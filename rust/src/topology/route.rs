//! All-pairs host path cache.
//!
//! The schedulers query `route(src, dst)` for every task x candidate-node
//! pair on the hot path; BFS per query is O(E) and shows up in profiles
//! (see EXPERIMENTS.md §Perf). [`PathCache`] precomputes host-to-host
//! link paths once per topology change.
//!
//! Two representations live behind one lookup API (DESIGN.md §10):
//!
//! * **Flat** — an explicit all-pairs table, one rotated single-source
//!   BFS per host (O(H·E) build, O(H²) paths). Correct on any graph.
//! * **Two-tier** — for host/edge-switch/core-router fabrics (fat trees,
//!   Fig. 2-style trees) every path is determined by O(H + E) closed-form
//!   tables: each host's access link, its edge switch, and the core its
//!   rotated BFS would claim first. Build cost drops to one pass over the
//!   links and memory from O(H²) paths (≈7 GB at ten kilonodes) to O(H).
//!   Paths are synthesized per query as inline 4-link sequences that are
//!   **bit-identical** to the flat table's BFS output (property-pinned in
//!   `rust/tests/proptests.rs`).

use std::ops::Deref;

use super::graph::{Endpoint, LinkId, NodeId, SwitchId, Topology};

/// A cached path: a borrowed slice out of the flat table, or a small
/// inline sequence synthesized by the two-tier representation. Derefs to
/// `[LinkId]`, so call sites treat both alike.
#[derive(Debug, Clone, Copy)]
pub enum PathRef<'a> {
    Borrowed(&'a [LinkId]),
    Inline { len: u8, links: [LinkId; 4] },
}

impl Deref for PathRef<'_> {
    type Target = [LinkId];

    fn deref(&self) -> &[LinkId] {
        match self {
            PathRef::Borrowed(p) => p,
            PathRef::Inline { len, links } => &links[..*len as usize],
        }
    }
}

/// Immutable path cache over the task-node set.
#[derive(Debug, Clone)]
pub struct PathCache {
    n: usize,
    repr: Repr,
}

#[derive(Debug, Clone)]
enum Repr {
    /// paths[src * n + dst] — `None` if disconnected.
    Flat(Vec<Option<Vec<LinkId>>>),
    TwoTier(TwoTier),
}

/// Closed-form tables for two-tier fabrics: every host hangs off exactly
/// one edge switch, every edge switch uplinks to every core router, and
/// no other links exist.
#[derive(Debug, Clone)]
struct TwoTier {
    /// Each host's single access link.
    host_link: Vec<LinkId>,
    /// Each host's edge switch.
    host_edge: Vec<usize>,
    /// The core router a source's rotated BFS claims first (the static
    /// ECMP hash `routes_from(src, src)` implements).
    chosen_core: Vec<usize>,
    /// uplink[edge * n_cores + core].
    uplink: Vec<LinkId>,
    n_cores: usize,
}

impl TwoTier {
    fn path(&self, src: NodeId, dst: NodeId) -> PathRef<'_> {
        let (s, d) = (src.0, dst.0);
        if s == d {
            return PathRef::Inline { len: 0, links: [LinkId(0); 4] };
        }
        let (es, ed) = (self.host_edge[s], self.host_edge[d]);
        if es == ed {
            return PathRef::Inline {
                len: 2,
                links: [self.host_link[s], self.host_link[d], LinkId(0), LinkId(0)],
            };
        }
        let c = self.chosen_core[s];
        PathRef::Inline {
            len: 4,
            links: [
                self.host_link[s],
                self.uplink[es * self.n_cores + c],
                self.uplink[ed * self.n_cores + c],
                self.host_link[d],
            ],
        }
    }
}

/// Structural detection: `Some` iff the topology is exactly two-tier, in
/// which case the closed form reproduces every rotated-BFS path. Any
/// deviation (multihomed or isolated host, host-host or switch-switch
/// link, parallel or missing uplinks) falls back to the flat table.
fn two_tier(topo: &Topology) -> Option<TwoTier> {
    let n = topo.n_hosts();
    let n_edges = topo.switches.len();
    let n_cores = topo.routers.len();
    if n == 0 || n_edges == 0 || n_cores == 0 {
        return None;
    }
    let mut host_link = vec![usize::MAX; n];
    let mut host_edge = vec![usize::MAX; n];
    let mut uplink = vec![usize::MAX; n_edges * n_cores];
    for l in &topo.links {
        match (l.a, l.b) {
            (Endpoint::Host(h), Endpoint::Switch(s)) | (Endpoint::Switch(s), Endpoint::Host(h)) => {
                if host_link[h.0] != usize::MAX {
                    return None; // multihomed host: BFS tie-breaks, no closed form
                }
                host_link[h.0] = l.id.0;
                host_edge[h.0] = s.0;
            }
            (Endpoint::Switch(s), Endpoint::Router(r))
            | (Endpoint::Router(r), Endpoint::Switch(s)) => {
                let k = s.0 * n_cores + r;
                if uplink[k] != usize::MAX {
                    return None; // parallel uplinks: BFS tie-breaks
                }
                uplink[k] = l.id.0;
            }
            _ => return None,
        }
    }
    if host_link.contains(&usize::MAX) || uplink.contains(&usize::MAX) {
        return None; // isolated host, or a (switch, router) pair unconnected
    }
    // The core a source claims first: from Host(s) the BFS expands its
    // edge switch with neighbor rotation `s`, and the first router in
    // that rotated scan is dequeued ahead of every other core, so it
    // claims all far edge switches (each core reaches each switch exactly
    // once). Replaying that one scan per host is the whole route choice.
    let chosen_core = (0..n)
        .map(|s| {
            let nbrs = topo.neighbors(Endpoint::Switch(SwitchId(host_edge[s])));
            let len = nbrs.len();
            (0..len)
                .find_map(|k| match nbrs[(k + s) % len].1 {
                    Endpoint::Router(r) => Some(r),
                    _ => None,
                })
                .expect("two-tier: every edge switch uplinks to every core")
        })
        .collect();
    Some(TwoTier {
        host_link: host_link.into_iter().map(LinkId).collect(),
        host_edge,
        chosen_core,
        uplink: uplink.into_iter().map(LinkId).collect(),
        n_cores,
    })
}

impl PathCache {
    /// Build from a topology. Two-tier fabrics get the hierarchical
    /// representation; everything else gets the flat table: one
    /// single-source BFS sweep per host (O(H·E) total; the seed ran a
    /// full BFS per *pair*, which priced thousand-host fat trees out
    /// entirely). Each source rotates its neighbor order by its own id,
    /// so multipath fabrics spread equal-length routes across parallel
    /// core links deterministically; trees are unaffected (unique
    /// shortest paths).
    pub fn build(topo: &Topology) -> Self {
        let n = topo.n_hosts();
        match two_tier(topo) {
            Some(t) => Self { n, repr: Repr::TwoTier(t) },
            None => Self::build_flat(topo),
        }
    }

    /// Force the explicit all-pairs table (the reference the two-tier
    /// representation is property-pinned against).
    pub fn build_flat(topo: &Topology) -> Self {
        let n = topo.n_hosts();
        let mut paths = Vec::with_capacity(n * n);
        for s in 0..n {
            paths.extend(topo.routes_from(NodeId(s), s));
        }
        Self { n, repr: Repr::Flat(paths) }
    }

    pub fn is_hierarchical(&self) -> bool {
        matches!(self.repr, Repr::TwoTier(_))
    }

    /// Cached path; empty for src == dst, `None` if disconnected.
    pub fn path(&self, src: NodeId, dst: NodeId) -> Option<PathRef<'_>> {
        match &self.repr {
            Repr::Flat(paths) => paths[src.0 * self.n + dst.0].as_deref().map(PathRef::Borrowed),
            Repr::TwoTier(t) => Some(t.path(src, dst)),
        }
    }

    pub fn n_hosts(&self) -> usize {
        self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::builders::{fat_tree, fig2, tree_cluster};

    #[test]
    fn cache_matches_bfs() {
        let f = fig2(100.0);
        let cache = PathCache::build(&f.topo);
        for s in 0..f.topo.n_hosts() {
            for d in 0..f.topo.n_hosts() {
                let want = f.topo.route(NodeId(s), NodeId(d));
                let got = cache.path(NodeId(s), NodeId(d)).map(|p| p.to_vec());
                // BFS may differ in path choice only if costs tie; Fig2 is
                // a tree so paths are unique.
                assert_eq!(got, want, "pair ({s},{d})");
            }
        }
    }

    #[test]
    fn self_path_is_empty() {
        let f = fig2(100.0);
        let cache = PathCache::build(&f.topo);
        assert!(cache.path(NodeId(0), NodeId(0)).unwrap().is_empty());
    }

    #[test]
    fn fig2_and_trees_use_hierarchical_repr() {
        assert!(PathCache::build(&fig2(100.0).topo).is_hierarchical());
        assert!(PathCache::build(&tree_cluster(3, 5, 100.0, 1000.0).0).is_hierarchical());
        assert!(PathCache::build(&fat_tree(4, 4, 4, 100.0, 1000.0).0).is_hierarchical());
    }

    fn all_pairs_agree(topo: &Topology) {
        let hier = PathCache::build(topo);
        let flat = PathCache::build_flat(topo);
        assert!(hier.is_hierarchical());
        assert!(!flat.is_hierarchical());
        for s in 0..topo.n_hosts() {
            for d in 0..topo.n_hosts() {
                let want = flat.path(NodeId(s), NodeId(d)).map(|p| p.to_vec());
                let got = hier.path(NodeId(s), NodeId(d)).map(|p| p.to_vec());
                assert_eq!(got, want, "pair ({s},{d})");
            }
        }
    }

    #[test]
    fn hierarchical_matches_flat_on_multicore_fat_tree() {
        all_pairs_agree(&fat_tree(4, 4, 4, 100.0, 1000.0).0);
        all_pairs_agree(&fat_tree(3, 5, 2, 100.0, 10_000.0).0);
    }

    #[test]
    fn hierarchical_matches_flat_on_trees() {
        all_pairs_agree(&fig2(100.0).topo);
        all_pairs_agree(&tree_cluster(4, 3, 100.0, 1000.0).0);
    }

    #[test]
    fn linkless_pair_falls_back_to_flat() {
        let mut t = Topology::new();
        let a = t.add_host();
        let b = t.add_host();
        let cache = PathCache::build(&t);
        assert!(!cache.is_hierarchical());
        assert!(cache.path(a, b).is_none());
        assert!(cache.path(a, a).unwrap().is_empty());
    }

    #[test]
    fn isolated_host_falls_back_to_flat() {
        let mut t = Topology::new();
        let a = t.add_host();
        let b = t.add_host();
        let s = t.add_switch();
        let r = t.add_router();
        t.connect(Endpoint::Host(a), Endpoint::Switch(s), 100.0);
        t.connect(Endpoint::Switch(s), Endpoint::Router(r), 1000.0);
        // b has no access link: closed form impossible
        let cache = PathCache::build(&t);
        assert!(!cache.is_hierarchical());
        assert!(cache.path(a, b).is_none());
    }

    #[test]
    fn host_to_host_link_falls_back_to_flat() {
        let mut t = Topology::new();
        let a = t.add_host();
        let b = t.add_host();
        let s = t.add_switch();
        let r = t.add_router();
        t.connect(Endpoint::Host(a), Endpoint::Switch(s), 100.0);
        t.connect(Endpoint::Host(b), Endpoint::Switch(s), 100.0);
        t.connect(Endpoint::Switch(s), Endpoint::Router(r), 1000.0);
        t.connect(Endpoint::Host(a), Endpoint::Host(b), 100.0);
        let cache = PathCache::build(&t);
        assert!(!cache.is_hierarchical());
        // the direct link is the shortest path
        assert_eq!(cache.path(a, b).unwrap().len(), 1);
    }
}
