//! Topology builders: the paper's Fig. 2 testbed and parameterized trees.

use super::graph::{Endpoint, LinkId, NodeId, Topology};

/// The paper's Fig. 2 cluster with its link numbering.
///
/// 4 task nodes, 2 OpenFlow switches, 1 router, 8 links:
///
/// * `Link1..Link4` — Node1..Node4 to their switch (N1,N2 on SW1; N3,N4 on SW2)
/// * `Link5`        — master node / scheduler to SW1
/// * `Link6`        — OpenFlow controller to SW2
/// * `Link7`        — SW1 to router
/// * `Link8`        — SW2 to router
///
/// This reproduces the paper's path example: moving TK1's input from ND3
/// to ND1 crosses Link3, Link8, Link7, Link1 (the paper lists the same
/// set, "Link 1, Link 7, Link 8 and Link 3").
#[derive(Debug, Clone)]
pub struct Fig2 {
    pub topo: Topology,
    /// ND_1..ND_4 (index 0..3).
    pub task_nodes: [NodeId; 4],
    /// Master/scheduler host (not a task node).
    pub master: NodeId,
    /// Controller host (not a task node).
    pub controller: NodeId,
    /// Link1..Link8 in the paper's numbering (index 0 == Link1).
    pub links: [LinkId; 8],
}

/// Build Fig. 2 with a uniform link rate in Mbps.
pub fn fig2(link_mbps: f64) -> Fig2 {
    let mut t = Topology::new();
    let n1 = t.add_host();
    let n2 = t.add_host();
    let n3 = t.add_host();
    let n4 = t.add_host();
    let master = t.add_host();
    let controller = t.add_host();
    let sw1 = t.add_switch();
    let sw2 = t.add_switch();
    let r = t.add_router();

    let l1 = t.connect(Endpoint::Host(n1), Endpoint::Switch(sw1), link_mbps);
    let l2 = t.connect(Endpoint::Host(n2), Endpoint::Switch(sw1), link_mbps);
    let l3 = t.connect(Endpoint::Host(n3), Endpoint::Switch(sw2), link_mbps);
    let l4 = t.connect(Endpoint::Host(n4), Endpoint::Switch(sw2), link_mbps);
    let l5 = t.connect(Endpoint::Host(master), Endpoint::Switch(sw1), link_mbps);
    let l6 = t.connect(Endpoint::Host(controller), Endpoint::Switch(sw2), link_mbps);
    let l7 = t.connect(Endpoint::Switch(sw1), Endpoint::Router(r), link_mbps);
    let l8 = t.connect(Endpoint::Switch(sw2), Endpoint::Router(r), link_mbps);

    Fig2 {
        topo: t,
        task_nodes: [n1, n2, n3, n4],
        master,
        controller,
        links: [l1, l2, l3, l4, l5, l6, l7, l8],
    }
}

/// Parameterized two-level tree: `n_switches` edge switches, each with
/// `hosts_per_switch` task nodes, all uplinked to one router.
///
/// Used for the Table I cluster (6 nodes: 2 switches x 3 hosts) and the
/// scale benches. Returns the topology and the task-node list in id order.
pub fn tree_cluster(
    n_switches: usize,
    hosts_per_switch: usize,
    edge_mbps: f64,
    uplink_mbps: f64,
) -> (Topology, Vec<NodeId>) {
    assert!(n_switches >= 1 && hosts_per_switch >= 1);
    let mut t = Topology::new();
    let mut hosts = Vec::with_capacity(n_switches * hosts_per_switch);
    // create hosts first so NodeId(0..n) are the task nodes
    for _ in 0..n_switches * hosts_per_switch {
        hosts.push(t.add_host());
    }
    let r = t.add_router();
    for s in 0..n_switches {
        let sw = t.add_switch();
        for h in 0..hosts_per_switch {
            let host = hosts[s * hosts_per_switch + h];
            t.connect(Endpoint::Host(host), Endpoint::Switch(sw), edge_mbps);
        }
        t.connect(Endpoint::Switch(sw), Endpoint::Router(r), uplink_mbps);
    }
    (t, hosts)
}

/// Two-tier fat tree (leaf-spine): `edge_switches` leaves with
/// `hosts_per_edge` task nodes each, every leaf uplinked to **all**
/// `core_switches` spine routers — the BigDataSDNSim-class datacenter
/// fabric the paper's future-work evaluation calls for. Returns the
/// topology and the task-node list in id order.
///
/// Each leaf lists its core uplinks starting at a different core
/// (`(edge + k) % cores`), and [`Topology::routes_from`] rotates by
/// source host; together they spread cross-leaf routes over the parallel
/// core links deterministically instead of funneling everything through
/// core 0.
pub fn fat_tree(
    edge_switches: usize,
    hosts_per_edge: usize,
    core_switches: usize,
    edge_mbps: f64,
    core_mbps: f64,
) -> (Topology, Vec<NodeId>) {
    assert!(edge_switches >= 1 && hosts_per_edge >= 1 && core_switches >= 1);
    let mut t = Topology::new();
    let mut hosts = Vec::with_capacity(edge_switches * hosts_per_edge);
    // create hosts first so NodeId(0..n) are the task nodes
    for _ in 0..edge_switches * hosts_per_edge {
        hosts.push(t.add_host());
    }
    let cores: Vec<usize> = (0..core_switches).map(|_| t.add_router()).collect();
    for e in 0..edge_switches {
        let sw = t.add_switch();
        for h in 0..hosts_per_edge {
            let host = hosts[e * hosts_per_edge + h];
            t.connect(Endpoint::Host(host), Endpoint::Switch(sw), edge_mbps);
        }
        for k in 0..core_switches {
            let core = cores[(e + k) % core_switches];
            t.connect(Endpoint::Switch(sw), Endpoint::Router(core), core_mbps);
        }
    }
    (t, hosts)
}

/// Rack id per host: the edge switch the host hangs off — the input the
/// Hadoop-style rack-aware placement policy needs. Hosts with no switch
/// link (degenerate topologies) get `usize::MAX` (rackless, treated as a
/// flat cluster by the policy when every host shares one rack).
///
/// One pass over the links (O(H + E)); a multihomed host keeps its first
/// host-switch link in link order, matching the per-host `find_map` scan
/// this replaced.
pub fn host_racks(topo: &Topology, hosts: &[NodeId]) -> Vec<usize> {
    let mut rack = vec![usize::MAX; topo.n_hosts()];
    for l in &topo.links {
        let (h, s) = match (l.a, l.b) {
            (Endpoint::Host(h), Endpoint::Switch(s)) | (Endpoint::Switch(s), Endpoint::Host(h)) => {
                (h, s)
            }
            _ => continue,
        };
        if rack[h.0] == usize::MAX {
            rack[h.0] = s.0;
        }
    }
    hosts.iter().map(|&h| rack[h.0]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_has_paper_shape() {
        let f = fig2(100.0);
        assert_eq!(f.topo.n_hosts(), 6); // 4 task + master + controller
        assert_eq!(f.topo.n_links(), 8);
        assert_eq!(f.topo.switches.len(), 2);
        assert_eq!(f.topo.routers.len(), 1);
    }

    #[test]
    fn fig2_nd3_to_nd1_uses_links_3_8_7_1() {
        let f = fig2(100.0);
        let p = f.topo.route(f.task_nodes[2], f.task_nodes[0]).unwrap();
        // paper: "Link 1, Link 7, Link 8 and Link 3" (as a set)
        let mut got = p.clone();
        got.sort();
        let mut want = vec![f.links[0], f.links[6], f.links[7], f.links[2]];
        want.sort();
        assert_eq!(got, want);
    }

    #[test]
    fn fig2_same_switch_path_is_two_links() {
        let f = fig2(100.0);
        let p = f.topo.route(f.task_nodes[1], f.task_nodes[0]).unwrap();
        let mut got = p;
        got.sort();
        assert_eq!(got, vec![f.links[0], f.links[1]]); // Link1 + Link2
    }

    #[test]
    fn tree_cluster_counts() {
        let (t, hosts) = tree_cluster(2, 3, 100.0, 1000.0);
        assert_eq!(hosts.len(), 6);
        assert_eq!(t.n_links(), 8); // 6 edge + 2 uplink
        // cross-switch route: host-sw, sw-r, r-sw, sw-host
        assert_eq!(t.route(hosts[0], hosts[5]).unwrap().len(), 4);
        // same-switch: 2 links
        assert_eq!(t.route(hosts[0], hosts[2]).unwrap().len(), 2);
    }

    #[test]
    fn tree_cluster_uplink_rate_applies() {
        let (t, hosts) = tree_cluster(2, 2, 100.0, 250.0);
        let p = t.route(hosts[0], hosts[3]).unwrap();
        let rates: Vec<f64> = p.iter().map(|&l| t.link(l).capacity_mbps).collect();
        assert_eq!(rates, vec![100.0, 250.0, 250.0, 100.0]);
    }

    #[test]
    fn fat_tree_counts_and_path_lengths() {
        let (t, hosts) = fat_tree(4, 3, 2, 100.0, 1000.0);
        assert_eq!(hosts.len(), 12);
        assert_eq!(t.switches.len(), 4);
        assert_eq!(t.routers.len(), 2);
        // 12 host links + 4 edges x 2 cores
        assert_eq!(t.n_links(), 20);
        // same-leaf: 2 links; cross-leaf: host-edge-core-edge-host
        assert_eq!(t.route(hosts[0], hosts[2]).unwrap().len(), 2);
        assert_eq!(t.route(hosts[0], hosts[11]).unwrap().len(), 4);
    }

    #[test]
    fn host_racks_follow_edge_switches() {
        let (t, hosts) = tree_cluster(2, 3, 100.0, 1000.0);
        assert_eq!(host_racks(&t, &hosts), vec![0, 0, 0, 1, 1, 1]);
        let f = fig2(100.0);
        // ND1, ND2 on SW1; ND3, ND4 on SW2
        assert_eq!(host_racks(&f.topo, &f.task_nodes), vec![0, 0, 1, 1]);
        let (ft, fh) = fat_tree(2, 2, 2, 100.0, 1000.0);
        assert_eq!(host_racks(&ft, &fh), vec![0, 0, 1, 1]);
    }

    #[test]
    fn fat_tree_spreads_routes_across_cores() {
        use crate::topology::PathCache;
        let (t, hosts) = fat_tree(4, 4, 4, 100.0, 1000.0);
        let cache = PathCache::build(&t);
        // collect the core-uplink links used by cross-leaf routes; with 4
        // parallel cores more than one must carry traffic
        let mut used = std::collections::HashSet::new();
        for &s in &hosts {
            for &d in &hosts {
                if s.0 / 4 == d.0 / 4 {
                    continue;
                }
                let p = cache.path(s, d).unwrap();
                assert_eq!(p.len(), 4, "cross-leaf routes are 4 links");
                used.insert(p[1]); // the src leaf's uplink
            }
        }
        assert!(used.len() > 1, "ECMP spread must use multiple core links, got {used:?}");
    }
}
