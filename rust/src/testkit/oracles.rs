//! Invariant oracles: global safety properties any executed simulation —
//! static or churned — must satisfy, re-derived independently of the
//! engine/calendar bookkeeping they check.
//!
//! Driven by `rust/tests/invariants.rs` over [`crate::testkit::forall`]-
//! generated random `DynamicsSpec`s for all schedulers:
//!
//! 1. no surviving task record overlaps a downtime window of its node;
//! 2. every submitted task completes exactly once (crash-voided attempts
//!    are re-run, nothing is lost or duplicated);
//! 3. committed slot reservations never oversubscribe a link's
//!    (time-varying) usable capacity — per-slot sums recomputed here
//!    from the audit log, not read back from the calendar;
//! 4. the makespan respects the critical-path and total-work lower
//!    bounds (transfers, downtime and stragglers can only add time).
//!
//! And over randomized concurrent job streams (`scenario::online`):
//!
//! 5. per-job exactly-once completion (no job loses or duplicates tasks
//!    to another job sharing the cluster);
//! 6. no slot double-booking — per node, record occupancy windows
//!    (pick-up to finish) never overlap, across jobs;
//! 7. cross-job reservation sums per slot stay within link capacity
//!    (oracle 3 over the one shared calendar);
//! 8. the stream makespan respects every job's release-time-plus-
//!    critical-path bound and the aggregate work bound.
//!
//! And over multi-tenant streams (`[tenants]` + DRF admission,
//! [`check_tenancy`]):
//!
//! 12. no tenant's admitted slot occupancy ever exceeds its quota, at
//!     any instant (boundary sweep over admission/finish events);
//! 13. every preempted spot task still completes exactly once;
//! 14. preemption victims are spot tenants only, and the preemptor is a
//!     guaranteed one;
//! 15. every DRF admission decision is reproducible from its audited
//!     share keys (the winner really was the tie-broken minimum).

use std::collections::HashMap;

use crate::mapreduce::{TaskId, TaskSpec};
use crate::scenario::{
    DuelAudit, DynamicsOutcome, PullAudit, ReallocAudit, ReservationAudit, StreamOutcome,
    TenantClass,
};
use crate::sim::TaskRecord;
use crate::topology::NodeId;
use crate::util::Secs;

/// Slack for float accumulation in the oracle arithmetic.
const EPS: f64 = 1e-6;

/// Oracle 1: no record's occupancy window (picked → finish) intersects a
/// downtime window of its node.
pub fn no_task_on_down_node(
    records: &[TaskRecord],
    down: &[(NodeId, Secs, Secs)],
) -> Result<(), String> {
    for r in records {
        for &(nd, d0, d1) in down {
            if r.node == nd && r.picked_at < d1 && r.finish > d0 {
                return Err(format!(
                    "task {:?} occupied node {:?} over [{}, {}] while it was down [{}, {}]",
                    r.task, r.node, r.picked_at, r.finish, d0, d1
                ));
            }
        }
    }
    Ok(())
}

/// Oracle 2: the surviving records cover the submitted task ids exactly
/// once each.
pub fn tasks_complete_exactly_once(
    submitted: &[TaskId],
    records: &[TaskRecord],
) -> Result<(), String> {
    let mut want: Vec<TaskId> = submitted.to_vec();
    want.sort();
    let mut got: Vec<TaskId> = records.iter().map(|r| r.task).collect();
    got.sort();
    for w in got.windows(2) {
        if w[0] == w[1] {
            return Err(format!("task {:?} completed more than once", w[0]));
        }
    }
    if got != want {
        return Err(format!("completion mismatch: submitted {want:?}, completed {got:?}"));
    }
    Ok(())
}

/// Oracle 3: within every scheduling round, the per-slot sum of
/// committed reservation fractions on each link stays within the link's
/// usable capacity fraction at commit time. Recomputed with a plain
/// boundary sweep over the audit log — independent of the sparse
/// calendar's own segment arithmetic.
pub fn reservations_within_capacity(audits: &[ReservationAudit]) -> Result<(), String> {
    // (round, link) -> [(start, end, frac, usable)]
    let mut per: HashMap<(usize, usize), Vec<(usize, usize, f64, f64)>> = HashMap::new();
    for a in audits {
        if a.usable.len() != a.links.len() {
            return Err(format!(
                "audit carries {} usable entries for {} links",
                a.usable.len(),
                a.links.len()
            ));
        }
        for (i, &l) in a.links.iter().enumerate() {
            per.entry((a.round, l.0)).or_default().push((
                a.start_slot,
                a.start_slot + a.n_slots,
                a.frac,
                a.usable[i],
            ));
        }
    }
    for (&(round, link), v) in &per {
        let mut bounds: Vec<usize> = v.iter().flat_map(|&(s, e, _, _)| [s, e]).collect();
        bounds.sort_unstable();
        bounds.dedup();
        for w in bounds.windows(2) {
            let (s, e) = (w[0], w[1]);
            let mut sum = 0.0f64;
            let mut usable = 1.0f64;
            for &(a, b, f, u) in v {
                if a < e && b > s {
                    sum += f;
                    usable = usable.min(u);
                }
            }
            if sum > usable + EPS {
                return Err(format!(
                    "round {round}: link {link} slots [{s}, {e}) reserved {sum:.6} of a {usable:.6} ceiling"
                ));
            }
        }
    }
    Ok(())
}

/// Oracle 4: `makespan >= max(critical-path bound, total-work bound)`.
/// Both bounds assume the best case — every node up the whole run, no
/// transfer time, base (non-straggling) speeds — so churn can only push
/// the real makespan above them.
pub fn makespan_lower_bounds(
    records: &[TaskRecord],
    tasks: &[TaskSpec],
    authorized: &[NodeId],
    node_speed: &[f64],
) -> Result<(), String> {
    if tasks.is_empty() || authorized.is_empty() {
        return Ok(());
    }
    let factor = |nd: NodeId| match node_speed.get(nd.0) {
        Some(&f) if f > 0.0 => f,
        _ => 1.0,
    };
    let min_tp = |t: &TaskSpec| {
        authorized.iter().map(|&nd| t.compute.0 * factor(nd)).fold(f64::INFINITY, f64::min)
    };
    let cp = tasks.iter().map(min_tp).fold(0.0f64, f64::max);
    let work: f64 = tasks.iter().map(min_tp).sum::<f64>() / authorized.len() as f64;
    let bound = cp.max(work);
    let makespan = records.iter().map(|r| r.finish.0).fold(0.0f64, f64::max);
    if makespan + EPS < bound {
        return Err(format!(
            "makespan {makespan:.6} below the lower bound {bound:.6} (cp {cp:.6}, work {work:.6})"
        ));
    }
    Ok(())
}

/// Oracle 9: no pull from a down node — every committed remote pull's
/// source was outside all of its downtime windows at the instant the
/// scheduler chose it. This pins the replica-readability fix: the seed's
/// `least_loaded_replica` ignored node health, so a crashed holder could
/// be picked as a transfer source under `[dynamics]`.
pub fn pulls_from_live_sources(
    pulls: &[PullAudit],
    down: &[(NodeId, Secs, Secs)],
) -> Result<(), String> {
    for p in pulls {
        for &(nd, d0, d1) in down {
            if p.source == nd && d0 <= p.at && p.at < d1 {
                return Err(format!(
                    "task {:?} was scheduled at {} to pull from {:?}, down over [{}, {})",
                    p.task, p.at, p.source, d0, d1
                ));
            }
        }
    }
    Ok(())
}

/// Oracle 10: killed speculation attempts never leak a calendar grant —
/// for every duel, whichever attempt lost (or both, in a crash storm
/// with no winner) must have had its committed reservation released.
/// Checked over the duel audit log, independent of the controller's own
/// flow/calendar bookkeeping.
pub fn no_leaked_speculation_grants(duels: &[DuelAudit]) -> Result<(), String> {
    for d in duels {
        let dup_lost = d.winner != Some(d.dup);
        let orig_lost = d.winner != Some(d.task);
        if dup_lost && d.reserved && !d.released {
            return Err(format!(
                "duel {:?}/{:?} (round {}): losing duplicate kept its calendar grant",
                d.task, d.dup, d.round
            ));
        }
        if orig_lost && d.orig_reserved && !d.orig_released {
            return Err(format!(
                "duel {:?}/{:?} (round {}): killed original kept its calendar grant",
                d.task, d.dup, d.round
            ));
        }
    }
    Ok(())
}

/// Oracle 11: the closed loop's grant accounting is coherent — for every
/// task the reallocator touched, the audited old→new rows form an
/// unbroken chain in time order (row k's `old` is row k-1's `new`:
/// nothing renegotiated a grant the controller no longer held), and the
/// chain's final reservation is present in the reservation audit log
/// (the live grant is audited; the stale rows it replaced were
/// withdrawn). Double-commit leaks surface through oracle 3: a stale row
/// left in the log stacks with its replacement and blows the per-slot
/// capacity sweep.
pub fn reallocation_preserves_grant_accounting(
    reallocs: &[ReallocAudit],
    reservations: &[ReservationAudit],
) -> Result<(), String> {
    let mut chains: HashMap<TaskId, Vec<&ReallocAudit>> = HashMap::new();
    for r in reallocs {
        chains.entry(r.task).or_default().push(r); // log order = time order
    }
    for (task, chain) in &chains {
        for w in chain.windows(2) {
            if w[1].at < w[0].at {
                return Err(format!("task {task:?}: realloc audit rows out of time order"));
            }
            if w[1].old != w[0].new {
                return Err(format!(
                    "task {task:?}: realloc at {} renegotiated {:?}, but the previous \
                     reallocation left the grant at {:?}",
                    w[1].at, w[1].old, w[0].new
                ));
            }
        }
        let last = chain.last().expect("grouped chains are non-empty");
        if last.old == last.new {
            // a recorded row must witness drift in the reserved window
            // (rate-only renegotiations keep the window; they are legal
            // but the window pair then differs in neither field)
            continue;
        }
        if last.new.n_slots > 0
            && !reservations.iter().any(|a| {
                a.round == last.round
                    && a.links == last.new.links
                    && a.start_slot == last.new.start_slot
                    && a.n_slots == last.new.n_slots
                    && a.frac == last.new.frac
            })
        {
            return Err(format!(
                "task {task:?}: the live reallocated grant {:?} (round {}) is missing \
                 from the reservation audit log",
                last.new, last.round
            ));
        }
    }
    Ok(())
}

/// Oracle 6: per node, no two records' occupancy windows (pick-up to
/// finish) overlap — the node FIFO must serialize tasks across jobs.
pub fn no_slot_double_booking(records: &[TaskRecord]) -> Result<(), String> {
    let mut per: HashMap<usize, Vec<(Secs, Secs, TaskId)>> = HashMap::new();
    for r in records {
        per.entry(r.node.0).or_default().push((r.picked_at, r.finish, r.task));
    }
    for (node, v) in &mut per {
        v.sort_by(|a, b| (a.0, a.2).cmp(&(b.0, b.2)));
        for w in v.windows(2) {
            if w[1].0 .0 + EPS < w[0].1 .0 {
                return Err(format!(
                    "node {node}: task {:?} picked at {} while task {:?} occupied it until {}",
                    w[1].2, w[1].0, w[0].2, w[0].1
                ));
            }
        }
    }
    Ok(())
}

/// Oracle 8: the stream's last absolute finish respects (a) each job's
/// release time plus its critical-path bound, and (b) the earliest
/// release plus the aggregate best-case work spread over the cluster.
/// Both relaxations assume zero transfer time and no contention, so the
/// real stream can only finish later.
pub fn stream_makespan_lower_bound(
    jobs: &[(Secs, Vec<TaskSpec>)],
    last_finish: f64,
    authorized: &[NodeId],
    node_speed: &[f64],
) -> Result<(), String> {
    if authorized.is_empty() {
        return Ok(());
    }
    let factor = |nd: NodeId| match node_speed.get(nd.0) {
        Some(&f) if f > 0.0 => f,
        _ => 1.0,
    };
    let min_tp = |t: &TaskSpec| {
        authorized.iter().map(|&nd| t.compute.0 * factor(nd)).fold(f64::INFINITY, f64::min)
    };
    let mut total_work = 0.0f64;
    let mut min_submit = f64::INFINITY;
    for (submit, tasks) in jobs {
        if tasks.is_empty() {
            continue;
        }
        let cp = tasks.iter().map(min_tp).fold(0.0f64, f64::max);
        if last_finish + EPS < submit.0 + cp {
            return Err(format!(
                "stream finish {last_finish:.6} beats release {} + critical path {cp:.6}",
                submit.0
            ));
        }
        total_work += tasks.iter().map(min_tp).sum::<f64>();
        min_submit = min_submit.min(submit.0);
    }
    if total_work > 0.0 {
        let bound = min_submit + total_work / authorized.len() as f64;
        if last_finish + EPS < bound {
            return Err(format!(
                "stream finish {last_finish:.6} beats the aggregate work bound {bound:.6}"
            ));
        }
    }
    Ok(())
}

/// Oracle 12: at no instant does a tenant's admitted slot occupancy
/// (sum of task counts over its admitted, unfinished jobs) exceed its
/// slot quota. Recomputed with a boundary sweep over admission/finish
/// events — releases at an instant apply before admissions at the same
/// instant, matching the driver's done-then-admit order.
pub fn tenant_slot_quotas_respected(outcome: &StreamOutcome) -> Result<(), String> {
    let tn = match &outcome.tenants {
        Some(t) => t,
        None => return Ok(()),
    };
    for ts in &tn.tenants {
        if ts.slot_quota == usize::MAX {
            continue;
        }
        // (time, delta) events for this tenant's jobs
        let mut events: Vec<(f64, i64)> = Vec::new();
        for j in &outcome.jobs {
            if j.rejected || j.tenant.as_deref() != Some(ts.name.as_str()) {
                continue;
            }
            let done = outcome
                .records
                .iter()
                .filter(|(job, _)| *job == j.job)
                .map(|(_, r)| r.finish.0)
                .fold(j.admitted_at, f64::max);
            events.push((j.admitted_at, j.tasks.len() as i64));
            events.push((done, -(j.tasks.len() as i64)));
        }
        events.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let mut held = 0i64;
        for (at, delta) in events {
            held += delta;
            if held > ts.slot_quota as i64 {
                return Err(format!(
                    "tenant {} held {held} slots at t={at} over a quota of {}",
                    ts.name, ts.slot_quota
                ));
            }
        }
    }
    Ok(())
}

/// Oracle 13: every preempted (drained and rescheduled) task still
/// completes exactly once — preemption moves work, it never loses or
/// duplicates it.
pub fn preempted_tasks_complete_exactly_once(outcome: &StreamOutcome) -> Result<(), String> {
    for p in &outcome.preemptions {
        let n = outcome.records.iter().filter(|(_, r)| r.task == p.task).count();
        if n != 1 {
            return Err(format!(
                "preempted task {:?} (victim {:?}) completed {n} times",
                p.task, p.victim
            ));
        }
    }
    Ok(())
}

/// Oracle 14: preemption only ever victimizes spot tenants, and is only
/// ever triggered by a guaranteed one.
pub fn only_spot_preempted(outcome: &StreamOutcome) -> Result<(), String> {
    let tn = match &outcome.tenants {
        Some(t) => t,
        None => {
            if outcome.preemptions.is_empty() {
                return Ok(());
            }
            return Err("preemptions recorded on a stream without tenancy".into());
        }
    };
    let class_of = |name: &str| {
        tn.tenants.iter().find(|t| t.name == name).map(|t| t.class)
    };
    for p in &outcome.preemptions {
        match class_of(&p.victim_tenant) {
            Some(TenantClass::Spot) => {}
            Some(TenantClass::Guaranteed) => {
                return Err(format!(
                    "guaranteed tenant {} was preempted (task {:?})",
                    p.victim_tenant, p.task
                ));
            }
            None => {
                return Err(format!("preemption victim tenant {} is unknown", p.victim_tenant));
            }
        }
        let by = outcome
            .jobs
            .iter()
            .find(|j| j.job == p.by)
            .and_then(|j| j.tenant.as_deref().and_then(class_of));
        if by != Some(TenantClass::Guaranteed) {
            return Err(format!(
                "preemption of {:?} was triggered by non-guaranteed job {:?}",
                p.task, p.by
            ));
        }
    }
    Ok(())
}

/// Oracle 15: every DRF admission decision is reproducible from its
/// audited per-tenant keys — the logged winner is the minimum finite
/// key, ties broken by larger weight then lower tenant index. A replayer
/// holding only the audit trail reaches the same admission order.
pub fn drf_admissions_reproducible(outcome: &StreamOutcome) -> Result<(), String> {
    let tn = match &outcome.tenants {
        Some(t) => t,
        None => return Ok(()),
    };
    for ad in &outcome.admissions {
        if ad.keys.len() != tn.tenants.len() {
            return Err(format!(
                "admission of {:?} logged {} keys for {} tenants",
                ad.job,
                ad.keys.len(),
                tn.tenants.len()
            ));
        }
        let w = ad.tenant;
        if w >= ad.keys.len() || !ad.keys[w].is_finite() {
            return Err(format!(
                "admission of {:?} picked tenant {w} with a non-finite key",
                ad.job
            ));
        }
        for (t, &k) in ad.keys.iter().enumerate() {
            if t == w || !k.is_finite() {
                continue;
            }
            let worse = ad.keys[w] < k
                || (ad.keys[w] == k
                    && (tn.tenants[w].weight > tn.tenants[t].weight
                        || (tn.tenants[w].weight == tn.tenants[t].weight && w < t)));
            if !worse {
                return Err(format!(
                    "admission of {:?} at t={} picked tenant {w} (key {}), but tenant \
                     {t} (key {k}) should have won the DRF tie-break",
                    ad.job, ad.at, ad.keys[w]
                ));
            }
        }
    }
    Ok(())
}

/// Oracles 12-15 over one multi-tenant stream run (no-ops without a
/// tenancy table).
pub fn check_tenancy(outcome: &StreamOutcome) -> Result<(), String> {
    tenant_slot_quotas_respected(outcome)?;
    preempted_tasks_complete_exactly_once(outcome)?;
    only_spot_preempted(outcome)?;
    drf_admissions_reproducible(outcome)
}

/// Oracles 5-8 (plus the grant-chain oracle 11 over drain/preemption
/// reallocations) over one concurrent stream run.
pub fn check_stream(
    outcome: &StreamOutcome,
    authorized: &[NodeId],
    node_speed: &[f64],
) -> Result<(), String> {
    // 5: per-job exactly-once completion over the job-tagged records
    // (rejected jobs never ran and must have no records)
    for j in &outcome.jobs {
        let recs: Vec<TaskRecord> = outcome
            .records
            .iter()
            .filter(|(job, _)| *job == j.job)
            .map(|(_, r)| r.clone())
            .collect();
        if j.rejected {
            if !recs.is_empty() {
                return Err(format!(
                    "rejected job {:?} ({}) left {} records",
                    j.job,
                    j.name,
                    recs.len()
                ));
            }
            continue;
        }
        let ids: Vec<TaskId> = j.tasks.iter().map(|t| t.id).collect();
        tasks_complete_exactly_once(&ids, &recs)
            .map_err(|e| format!("job {:?} ({}): {e}", j.job, j.name))?;
    }
    let total: usize =
        outcome.jobs.iter().filter(|j| !j.rejected).map(|j| j.tasks.len()).sum();
    if total != outcome.records.len() {
        return Err(format!(
            "{} records for {total} submitted tasks across the stream",
            outcome.records.len()
        ));
    }
    // 6: node FIFO serialization across jobs
    let plain: Vec<TaskRecord> = outcome.records.iter().map(|(_, r)| r.clone()).collect();
    no_slot_double_booking(&plain)?;
    // 7: cross-job per-slot reservation sums on the shared calendar
    reservations_within_capacity(&outcome.reservations)?;
    // 11: drain/preemption grant moves form coherent old→new chains
    reallocation_preserves_grant_accounting(&outcome.reallocs, &outcome.reservations)?;
    // 8: stream makespan bounds (admitted jobs only)
    let jobs: Vec<(Secs, Vec<TaskSpec>)> = outcome
        .jobs
        .iter()
        .filter(|j| !j.rejected)
        .map(|j| (Secs(j.submitted_at), j.tasks.clone()))
        .collect();
    stream_makespan_lower_bound(&jobs, outcome.last_finish, authorized, node_speed)
}

/// All dynamic-run oracles (1-4 plus 9-11) over one outcome.
pub fn check_dynamics(
    outcome: &DynamicsOutcome,
    tasks: &[TaskSpec],
    authorized: &[NodeId],
    node_speed: &[f64],
) -> Result<(), String> {
    no_task_on_down_node(&outcome.records, &outcome.down_intervals)?;
    tasks_complete_exactly_once(&outcome.submitted, &outcome.records)?;
    reservations_within_capacity(&outcome.reservations)?;
    pulls_from_live_sources(&outcome.pulls, &outcome.down_intervals)?;
    no_leaked_speculation_grants(&outcome.duels)?;
    reallocation_preserves_grant_accounting(&outcome.reallocs, &outcome.reservations)?;
    makespan_lower_bounds(&outcome.records, tasks, authorized, node_speed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::LinkId;

    fn rec(task: usize, node: usize, picked: f64, finish: f64) -> TaskRecord {
        TaskRecord {
            task: TaskId(task),
            node: NodeId(node),
            picked_at: Secs(picked),
            input_ready: Secs(picked),
            compute_start: Secs(picked),
            finish: Secs(finish),
            source: None,
            is_local: true,
            is_map: true,
        }
    }

    #[test]
    fn downtime_overlap_is_flagged() {
        let down = vec![(NodeId(0), Secs(5.0), Secs(10.0))];
        assert!(no_task_on_down_node(&[rec(0, 0, 0.0, 5.0)], &down).is_ok());
        assert!(no_task_on_down_node(&[rec(0, 0, 10.0, 12.0)], &down).is_ok());
        assert!(no_task_on_down_node(&[rec(0, 1, 6.0, 8.0)], &down).is_ok());
        assert!(no_task_on_down_node(&[rec(0, 0, 4.0, 6.0)], &down).is_err());
        assert!(no_task_on_down_node(&[rec(0, 0, 6.0, 7.0)], &down).is_err());
    }

    #[test]
    fn down_sources_are_flagged() {
        let down = vec![(NodeId(1), Secs(5.0), Secs(20.0))];
        let pull = |src: usize, at: f64| PullAudit {
            task: TaskId(0),
            source: NodeId(src),
            at: Secs(at),
        };
        assert!(pulls_from_live_sources(&[pull(0, 10.0)], &down).is_ok());
        assert!(pulls_from_live_sources(&[pull(1, 4.0)], &down).is_ok());
        assert!(pulls_from_live_sources(&[pull(1, 20.0)], &down).is_ok());
        assert!(pulls_from_live_sources(&[pull(1, 5.0)], &down).is_err());
        assert!(pulls_from_live_sources(&[pull(1, 12.0)], &down).is_err());
    }

    #[test]
    fn exactly_once_catches_loss_and_duplication() {
        let sub = vec![TaskId(0), TaskId(1)];
        assert!(tasks_complete_exactly_once(&sub, &[rec(0, 0, 0.0, 1.0), rec(1, 0, 1.0, 2.0)])
            .is_ok());
        assert!(tasks_complete_exactly_once(&sub, &[rec(0, 0, 0.0, 1.0)]).is_err());
        assert!(tasks_complete_exactly_once(
            &sub,
            &[rec(0, 0, 0.0, 1.0), rec(1, 0, 1.0, 2.0), rec(1, 1, 1.0, 2.0)]
        )
        .is_err());
    }

    #[test]
    fn reservation_sweep_catches_oversubscription() {
        let audit = |round: usize, start: usize, n: usize, frac: f64, usable: f64| {
            ReservationAudit {
                round,
                links: vec![LinkId(0)],
                start_slot: start,
                n_slots: n,
                frac,
                usable: vec![usable],
            }
        };
        // two half-rate windows stack to exactly the ceiling: fine
        assert!(reservations_within_capacity(&[
            audit(1, 0, 5, 0.5, 1.0),
            audit(1, 2, 5, 0.5, 1.0)
        ])
        .is_ok());
        // stacked beyond the (degraded) ceiling: flagged
        assert!(reservations_within_capacity(&[
            audit(1, 0, 5, 0.5, 0.6),
            audit(1, 2, 5, 0.5, 0.6)
        ])
        .is_err());
        // different rounds never stack (each round re-reserves afresh)
        assert!(reservations_within_capacity(&[
            audit(1, 0, 5, 0.8, 1.0),
            audit(2, 0, 5, 0.8, 1.0)
        ])
        .is_ok());
    }

    #[test]
    fn leaked_speculation_grants_are_flagged() {
        let duel = |winner: Option<usize>, reserved: bool, released: bool,
                    orig_reserved: bool, orig_released: bool| {
            DuelAudit {
                round: 1,
                task: TaskId(3),
                dup: TaskId(3 + crate::scenario::mitigation::DUP_BASE),
                node: NodeId(1),
                at: Secs(10.0),
                winner: winner.map(TaskId),
                reserved,
                released,
                orig_reserved,
                orig_released,
            }
        };
        let dup = 3 + crate::scenario::mitigation::DUP_BASE;
        // dup won, orig's grant released: fine
        assert!(no_leaked_speculation_grants(&[duel(Some(dup), true, false, true, true)])
            .is_ok());
        // dup won but the killed original kept its grant: flagged
        assert!(no_leaked_speculation_grants(&[duel(Some(dup), true, false, true, false)])
            .is_err());
        // orig won, dup's grant released: fine
        assert!(no_leaked_speculation_grants(&[duel(Some(3), true, true, false, false)])
            .is_ok());
        // orig won but the losing dup kept its grant: flagged
        assert!(no_leaked_speculation_grants(&[duel(Some(3), true, false, false, false)])
            .is_err());
        // crash storm (no winner): both grants must be released
        assert!(no_leaked_speculation_grants(&[duel(None, true, true, true, true)]).is_ok());
        assert!(no_leaked_speculation_grants(&[duel(None, true, true, true, false)])
            .is_err());
        // unreserved attempts can't leak
        assert!(no_leaked_speculation_grants(&[duel(None, false, false, false, false)])
            .is_ok());
    }

    #[test]
    fn realloc_chains_must_be_unbroken_and_end_in_the_audit_log() {
        use crate::sdn::Reservation;
        let resv = |start: usize, frac: f64| Reservation {
            links: vec![LinkId(0), LinkId(1)],
            start_slot: start,
            n_slots: 4,
            frac,
        };
        let row = |at: f64, old: Reservation, new: Reservation| ReallocAudit {
            round: 1,
            task: TaskId(7),
            at: Secs(at),
            old,
            new,
            class_share_mb_s: 5.0,
        };
        let audit_of = |r: &Reservation| ReservationAudit {
            round: 1,
            links: r.links.clone(),
            start_slot: r.start_slot,
            n_slots: r.n_slots,
            frac: r.frac,
            usable: vec![1.0, 1.0],
        };
        // a two-hop chain whose final window is audited: fine
        let chain =
            vec![row(5.0, resv(10, 0.5), resv(14, 0.5)), row(9.0, resv(14, 0.5), resv(12, 0.4))];
        let log = vec![audit_of(&resv(12, 0.4))];
        assert!(reallocation_preserves_grant_accounting(&chain, &log).is_ok());
        // broken chain: the second row renegotiates a window the
        // controller never held after the first
        let broken =
            vec![row(5.0, resv(10, 0.5), resv(14, 0.5)), row(9.0, resv(11, 0.5), resv(12, 0.4))];
        assert!(reallocation_preserves_grant_accounting(&broken, &log).is_err());
        // out of time order: flagged
        let unordered =
            vec![row(9.0, resv(10, 0.5), resv(14, 0.5)), row(5.0, resv(14, 0.5), resv(12, 0.4))];
        assert!(reallocation_preserves_grant_accounting(&unordered, &log).is_err());
        // the live grant vanished from the reservation log: flagged
        let stale_log = vec![audit_of(&resv(14, 0.5))];
        assert!(reallocation_preserves_grant_accounting(&chain, &stale_log).is_err());
        // no reallocations: trivially coherent
        assert!(reallocation_preserves_grant_accounting(&[], &[]).is_ok());
    }

    #[test]
    fn double_booking_is_flagged() {
        // serial on one node: fine
        let ok = vec![rec(0, 0, 0.0, 5.0), rec(1, 0, 5.0, 9.0), rec(2, 1, 1.0, 3.0)];
        assert!(no_slot_double_booking(&ok).is_ok());
        // overlapping windows on one node: flagged
        let bad = vec![rec(0, 0, 0.0, 5.0), rec(1, 0, 4.0, 9.0)];
        assert!(no_slot_double_booking(&bad).is_err());
        // same windows on different nodes: fine
        let split = vec![rec(0, 0, 0.0, 5.0), rec(1, 1, 0.0, 5.0)];
        assert!(no_slot_double_booking(&split).is_ok());
        // zero-width record at a boundary: fine
        let zero = vec![rec(0, 0, 0.0, 5.0), rec(1, 0, 5.0, 5.0), rec(2, 0, 5.0, 8.0)];
        assert!(no_slot_double_booking(&zero).is_ok());
    }

    #[test]
    fn stream_bounds_hold_and_flag_impossible_streams() {
        use crate::hdfs::BlockId;
        let wave = |n: usize| -> Vec<TaskSpec> {
            (0..n).map(|i| TaskSpec::map(i, BlockId(0), 64.0, Secs(10.0), 0.0)).collect()
        };
        let nodes = [NodeId(0), NodeId(1)];
        // two 2-task jobs released at 0 and 100: work bound 20, release
        // bound 110
        let jobs = vec![(Secs(0.0), wave(2)), (Secs(100.0), wave(2))];
        assert!(stream_makespan_lower_bound(&jobs, 110.0, &nodes, &[]).is_ok());
        // beats the second job's release + critical path
        assert!(stream_makespan_lower_bound(&jobs, 105.0, &nodes, &[]).is_err());
        // beats the aggregate work bound: 4 x 10s on 2 nodes from t=0
        let burst = vec![(Secs(0.0), wave(2)), (Secs(0.0), wave(2))];
        assert!(stream_makespan_lower_bound(&burst, 15.0, &nodes, &[]).is_err());
        assert!(stream_makespan_lower_bound(&burst, 20.0, &nodes, &[]).is_ok());
    }

    mod tenancy {
        use super::*;
        use crate::mapreduce::JobId;
        use crate::metrics::{JobMetrics, StreamStats};
        use crate::scenario::{
            AdmissionAudit, JobOutcome, PreemptionAudit, TenancySpec, TenantSpec,
        };
        use crate::util::Secs;

        fn empty_outcome(tenants: Option<TenancySpec>) -> StreamOutcome {
            StreamOutcome {
                jobs: Vec::new(),
                records: Vec::new(),
                reservations: Vec::new(),
                last_finish: 0.0,
                makespan: 0.0,
                stats: StreamStats::from_jobs(&[], &[]),
                queued_jobs: 0,
                rebalances: 0,
                tenants,
                tenant_stats: Vec::new(),
                fairness_jain: 1.0,
                admissions: Vec::new(),
                preemptions: Vec::new(),
                reallocs: Vec::new(),
                rejected_jobs: 0,
            }
        }

        fn job(jid: usize, tenant: &str, admitted: f64, n_tasks: usize) -> JobOutcome {
            use crate::hdfs::BlockId;
            let base = jid * 10;
            JobOutcome {
                job: JobId(jid),
                name: format!("j{jid}"),
                submitted_at: admitted,
                admitted_at: admitted,
                gate: admitted,
                queued: false,
                metrics: JobMetrics { mt: 0.0, rt: 0.0, jt: 0.0, lr: 1.0 },
                isolated_jt: 0.0,
                slowdown: 1.0,
                tasks: (0..n_tasks)
                    .map(|i| TaskSpec::map(base + i, BlockId(0), 64.0, Secs(10.0), 0.0))
                    .collect(),
                tenant: Some(tenant.into()),
                rejected: false,
            }
        }

        fn spec(quota: usize) -> TenancySpec {
            let mut a = TenantSpec::named("a");
            a.slot_quota = quota;
            TenancySpec { tenants: vec![a] }
        }

        #[test]
        fn quota_sweep_flags_instantaneous_oversubscription() {
            // j0 holds [0, 10), j1 holds [10, 20): back-to-back at the
            // boundary stays within a 2-slot quota (release-then-admit)
            let mut out = empty_outcome(Some(spec(2)));
            out.jobs = vec![job(0, "a", 0.0, 2), job(1, "a", 10.0, 2)];
            out.records = vec![
                (JobId(0), rec(0, 0, 0.0, 10.0)),
                (JobId(0), rec(1, 1, 0.0, 10.0)),
                (JobId(1), rec(10, 0, 10.0, 20.0)),
                (JobId(1), rec(11, 1, 10.0, 20.0)),
            ];
            assert!(tenant_slot_quotas_respected(&out).is_ok());
            // overlapping holds breach the quota at t=5
            out.jobs[1].admitted_at = 5.0;
            out.records[2].1 = rec(10, 0, 5.0, 20.0);
            assert!(tenant_slot_quotas_respected(&out).is_err());
            // an uncapped tenant never trips the sweep
            let mut free = empty_outcome(Some(spec(usize::MAX)));
            free.jobs = out.jobs.clone();
            free.records = out.records.clone();
            assert!(tenant_slot_quotas_respected(&free).is_ok());
        }

        #[test]
        fn preempted_tasks_must_still_complete_exactly_once() {
            let mut out = empty_outcome(Some(spec(usize::MAX)));
            let hit = |task: usize| PreemptionAudit {
                at: 1.0,
                task: TaskId(task),
                victim: JobId(0),
                victim_tenant: "a".into(),
                by: JobId(1),
            };
            out.records = vec![(JobId(0), rec(3, 0, 5.0, 9.0))];
            out.preemptions = vec![hit(3)];
            assert!(preempted_tasks_complete_exactly_once(&out).is_ok());
            // a lost preempted task is flagged
            out.preemptions = vec![hit(4)];
            assert!(preempted_tasks_complete_exactly_once(&out).is_err());
            // and so is a duplicated one
            out.preemptions = vec![hit(3)];
            out.records.push((JobId(0), rec(3, 1, 9.0, 12.0)));
            assert!(preempted_tasks_complete_exactly_once(&out).is_err());
        }

        #[test]
        fn preemption_class_rules_are_enforced() {
            let mut prod = TenantSpec::named("prod");
            prod.class = TenantClass::Guaranteed;
            let batch = TenantSpec::named("batch");
            let tn = TenancySpec { tenants: vec![prod, batch] };
            let mut out = empty_outcome(Some(tn));
            out.jobs = vec![job(0, "batch", 0.0, 1), job(1, "prod", 1.0, 1)];
            out.records = vec![
                (JobId(0), rec(0, 0, 0.0, 5.0)),
                (JobId(1), rec(10, 1, 1.0, 4.0)),
            ];
            let hit = |victim_tenant: &str, by: usize| PreemptionAudit {
                at: 1.0,
                task: TaskId(0),
                victim: JobId(0),
                victim_tenant: victim_tenant.into(),
                by: JobId(by),
            };
            out.preemptions = vec![hit("batch", 1)];
            assert!(only_spot_preempted(&out).is_ok());
            // a guaranteed victim is flagged
            out.preemptions = vec![hit("prod", 1)];
            assert!(only_spot_preempted(&out).is_err());
            // a spot preemptor is flagged
            out.preemptions = vec![hit("batch", 0)];
            assert!(only_spot_preempted(&out).is_err());
        }

        #[test]
        fn drf_decisions_must_match_their_logged_keys() {
            let mut heavy = TenantSpec::named("heavy");
            heavy.weight = 2.0;
            let light = TenantSpec::named("light");
            let tn = TenancySpec { tenants: vec![heavy, light] };
            let mut out = empty_outcome(Some(tn));
            let pick = |tenant: usize, keys: Vec<f64>| AdmissionAudit {
                at: 0.0,
                job: JobId(0),
                tenant,
                keys,
            };
            // clear minimum
            out.admissions = vec![pick(1, vec![0.5, 0.1])];
            assert!(drf_admissions_reproducible(&out).is_ok());
            // winner was not the minimum: flagged
            out.admissions = vec![pick(0, vec![0.5, 0.1])];
            assert!(drf_admissions_reproducible(&out).is_err());
            // equal keys: the heavier tenant must win
            out.admissions = vec![pick(0, vec![0.2, 0.2])];
            assert!(drf_admissions_reproducible(&out).is_ok());
            out.admissions = vec![pick(1, vec![0.2, 0.2])];
            assert!(drf_admissions_reproducible(&out).is_err());
            // an ineligible (infinite-key) rival never outranks the pick
            out.admissions = vec![pick(0, vec![0.9, f64::INFINITY])];
            assert!(drf_admissions_reproducible(&out).is_ok());
            // picking an ineligible tenant is flagged
            out.admissions = vec![pick(1, vec![0.9, f64::INFINITY])];
            assert!(drf_admissions_reproducible(&out).is_err());
        }

        #[test]
        fn check_stream_tolerates_rejected_jobs() {
            let mut out = empty_outcome(Some(spec(1)));
            let mut ok = job(0, "a", 0.0, 1);
            ok.tasks = vec![TaskSpec::map(0, crate::hdfs::BlockId(0), 64.0, Secs(10.0), 0.0)];
            let mut rej = job(1, "a", 1.0, 2);
            rej.rejected = true;
            out.jobs = vec![ok, rej];
            out.records = vec![(JobId(0), rec(0, 0, 0.0, 10.0))];
            out.last_finish = 10.0;
            let nodes = [NodeId(0)];
            assert!(check_stream(&out, &nodes, &[]).is_ok());
            // a rejected job with records is flagged
            out.records.push((JobId(1), rec(10, 0, 10.0, 12.0)));
            assert!(check_stream(&out, &nodes, &[]).is_err());
        }
    }

    #[test]
    fn makespan_bounds_hold_and_flag_impossible_runs() {
        use crate::hdfs::BlockId;
        let tasks: Vec<TaskSpec> =
            (0..4).map(|i| TaskSpec::map(i, BlockId(0), 64.0, Secs(10.0), 0.0)).collect();
        let nodes = [NodeId(0), NodeId(1)];
        // 4 x 10s on 2 nodes: work bound 20s, cp bound 10s
        let ok: Vec<TaskRecord> = (0..4)
            .map(|i| rec(i, i % 2, (i / 2) as f64 * 10.0, (i / 2 + 1) as f64 * 10.0))
            .collect();
        assert!(makespan_lower_bounds(&ok, &tasks, &nodes, &[]).is_ok());
        let impossible: Vec<TaskRecord> = (0..4).map(|i| rec(i, i % 2, 0.0, 12.0)).collect();
        assert!(makespan_lower_bounds(&impossible, &tasks, &nodes, &[]).is_err());
    }
}
