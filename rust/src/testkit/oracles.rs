//! Invariant oracles: global safety properties any executed simulation —
//! static or churned — must satisfy, re-derived independently of the
//! engine/calendar bookkeeping they check.
//!
//! Driven by `rust/tests/invariants.rs` over [`crate::testkit::forall`]-
//! generated random `DynamicsSpec`s for all schedulers:
//!
//! 1. no surviving task record overlaps a downtime window of its node;
//! 2. every submitted task completes exactly once (crash-voided attempts
//!    are re-run, nothing is lost or duplicated);
//! 3. committed slot reservations never oversubscribe a link's
//!    (time-varying) usable capacity — per-slot sums recomputed here
//!    from the audit log, not read back from the calendar;
//! 4. the makespan respects the critical-path and total-work lower
//!    bounds (transfers, downtime and stragglers can only add time).
//!
//! And over randomized concurrent job streams (`scenario::online`):
//!
//! 5. per-job exactly-once completion (no job loses or duplicates tasks
//!    to another job sharing the cluster);
//! 6. no slot double-booking — per node, record occupancy windows
//!    (pick-up to finish) never overlap, across jobs;
//! 7. cross-job reservation sums per slot stay within link capacity
//!    (oracle 3 over the one shared calendar);
//! 8. the stream makespan respects every job's release-time-plus-
//!    critical-path bound and the aggregate work bound.

use std::collections::HashMap;

use crate::mapreduce::{TaskId, TaskSpec};
use crate::scenario::{
    DuelAudit, DynamicsOutcome, PullAudit, ReallocAudit, ReservationAudit, StreamOutcome,
};
use crate::sim::TaskRecord;
use crate::topology::NodeId;
use crate::util::Secs;

/// Slack for float accumulation in the oracle arithmetic.
const EPS: f64 = 1e-6;

/// Oracle 1: no record's occupancy window (picked → finish) intersects a
/// downtime window of its node.
pub fn no_task_on_down_node(
    records: &[TaskRecord],
    down: &[(NodeId, Secs, Secs)],
) -> Result<(), String> {
    for r in records {
        for &(nd, d0, d1) in down {
            if r.node == nd && r.picked_at < d1 && r.finish > d0 {
                return Err(format!(
                    "task {:?} occupied node {:?} over [{}, {}] while it was down [{}, {}]",
                    r.task, r.node, r.picked_at, r.finish, d0, d1
                ));
            }
        }
    }
    Ok(())
}

/// Oracle 2: the surviving records cover the submitted task ids exactly
/// once each.
pub fn tasks_complete_exactly_once(
    submitted: &[TaskId],
    records: &[TaskRecord],
) -> Result<(), String> {
    let mut want: Vec<TaskId> = submitted.to_vec();
    want.sort();
    let mut got: Vec<TaskId> = records.iter().map(|r| r.task).collect();
    got.sort();
    for w in got.windows(2) {
        if w[0] == w[1] {
            return Err(format!("task {:?} completed more than once", w[0]));
        }
    }
    if got != want {
        return Err(format!("completion mismatch: submitted {want:?}, completed {got:?}"));
    }
    Ok(())
}

/// Oracle 3: within every scheduling round, the per-slot sum of
/// committed reservation fractions on each link stays within the link's
/// usable capacity fraction at commit time. Recomputed with a plain
/// boundary sweep over the audit log — independent of the sparse
/// calendar's own segment arithmetic.
pub fn reservations_within_capacity(audits: &[ReservationAudit]) -> Result<(), String> {
    // (round, link) -> [(start, end, frac, usable)]
    let mut per: HashMap<(usize, usize), Vec<(usize, usize, f64, f64)>> = HashMap::new();
    for a in audits {
        if a.usable.len() != a.links.len() {
            return Err(format!(
                "audit carries {} usable entries for {} links",
                a.usable.len(),
                a.links.len()
            ));
        }
        for (i, &l) in a.links.iter().enumerate() {
            per.entry((a.round, l.0)).or_default().push((
                a.start_slot,
                a.start_slot + a.n_slots,
                a.frac,
                a.usable[i],
            ));
        }
    }
    for (&(round, link), v) in &per {
        let mut bounds: Vec<usize> = v.iter().flat_map(|&(s, e, _, _)| [s, e]).collect();
        bounds.sort_unstable();
        bounds.dedup();
        for w in bounds.windows(2) {
            let (s, e) = (w[0], w[1]);
            let mut sum = 0.0f64;
            let mut usable = 1.0f64;
            for &(a, b, f, u) in v {
                if a < e && b > s {
                    sum += f;
                    usable = usable.min(u);
                }
            }
            if sum > usable + EPS {
                return Err(format!(
                    "round {round}: link {link} slots [{s}, {e}) reserved {sum:.6} of a {usable:.6} ceiling"
                ));
            }
        }
    }
    Ok(())
}

/// Oracle 4: `makespan >= max(critical-path bound, total-work bound)`.
/// Both bounds assume the best case — every node up the whole run, no
/// transfer time, base (non-straggling) speeds — so churn can only push
/// the real makespan above them.
pub fn makespan_lower_bounds(
    records: &[TaskRecord],
    tasks: &[TaskSpec],
    authorized: &[NodeId],
    node_speed: &[f64],
) -> Result<(), String> {
    if tasks.is_empty() || authorized.is_empty() {
        return Ok(());
    }
    let factor = |nd: NodeId| match node_speed.get(nd.0) {
        Some(&f) if f > 0.0 => f,
        _ => 1.0,
    };
    let min_tp = |t: &TaskSpec| {
        authorized.iter().map(|&nd| t.compute.0 * factor(nd)).fold(f64::INFINITY, f64::min)
    };
    let cp = tasks.iter().map(min_tp).fold(0.0f64, f64::max);
    let work: f64 = tasks.iter().map(min_tp).sum::<f64>() / authorized.len() as f64;
    let bound = cp.max(work);
    let makespan = records.iter().map(|r| r.finish.0).fold(0.0f64, f64::max);
    if makespan + EPS < bound {
        return Err(format!(
            "makespan {makespan:.6} below the lower bound {bound:.6} (cp {cp:.6}, work {work:.6})"
        ));
    }
    Ok(())
}

/// Oracle 9: no pull from a down node — every committed remote pull's
/// source was outside all of its downtime windows at the instant the
/// scheduler chose it. This pins the replica-readability fix: the seed's
/// `least_loaded_replica` ignored node health, so a crashed holder could
/// be picked as a transfer source under `[dynamics]`.
pub fn pulls_from_live_sources(
    pulls: &[PullAudit],
    down: &[(NodeId, Secs, Secs)],
) -> Result<(), String> {
    for p in pulls {
        for &(nd, d0, d1) in down {
            if p.source == nd && d0 <= p.at && p.at < d1 {
                return Err(format!(
                    "task {:?} was scheduled at {} to pull from {:?}, down over [{}, {})",
                    p.task, p.at, p.source, d0, d1
                ));
            }
        }
    }
    Ok(())
}

/// Oracle 10: killed speculation attempts never leak a calendar grant —
/// for every duel, whichever attempt lost (or both, in a crash storm
/// with no winner) must have had its committed reservation released.
/// Checked over the duel audit log, independent of the controller's own
/// flow/calendar bookkeeping.
pub fn no_leaked_speculation_grants(duels: &[DuelAudit]) -> Result<(), String> {
    for d in duels {
        let dup_lost = d.winner != Some(d.dup);
        let orig_lost = d.winner != Some(d.task);
        if dup_lost && d.reserved && !d.released {
            return Err(format!(
                "duel {:?}/{:?} (round {}): losing duplicate kept its calendar grant",
                d.task, d.dup, d.round
            ));
        }
        if orig_lost && d.orig_reserved && !d.orig_released {
            return Err(format!(
                "duel {:?}/{:?} (round {}): killed original kept its calendar grant",
                d.task, d.dup, d.round
            ));
        }
    }
    Ok(())
}

/// Oracle 11: the closed loop's grant accounting is coherent — for every
/// task the reallocator touched, the audited old→new rows form an
/// unbroken chain in time order (row k's `old` is row k-1's `new`:
/// nothing renegotiated a grant the controller no longer held), and the
/// chain's final reservation is present in the reservation audit log
/// (the live grant is audited; the stale rows it replaced were
/// withdrawn). Double-commit leaks surface through oracle 3: a stale row
/// left in the log stacks with its replacement and blows the per-slot
/// capacity sweep.
pub fn reallocation_preserves_grant_accounting(
    reallocs: &[ReallocAudit],
    reservations: &[ReservationAudit],
) -> Result<(), String> {
    let mut chains: HashMap<TaskId, Vec<&ReallocAudit>> = HashMap::new();
    for r in reallocs {
        chains.entry(r.task).or_default().push(r); // log order = time order
    }
    for (task, chain) in &chains {
        for w in chain.windows(2) {
            if w[1].at < w[0].at {
                return Err(format!("task {task:?}: realloc audit rows out of time order"));
            }
            if w[1].old != w[0].new {
                return Err(format!(
                    "task {task:?}: realloc at {} renegotiated {:?}, but the previous \
                     reallocation left the grant at {:?}",
                    w[1].at, w[1].old, w[0].new
                ));
            }
        }
        let last = chain.last().expect("grouped chains are non-empty");
        if last.old == last.new {
            // a recorded row must witness drift in the reserved window
            // (rate-only renegotiations keep the window; they are legal
            // but the window pair then differs in neither field)
            continue;
        }
        if last.new.n_slots > 0
            && !reservations.iter().any(|a| {
                a.round == last.round
                    && a.links == last.new.links
                    && a.start_slot == last.new.start_slot
                    && a.n_slots == last.new.n_slots
                    && a.frac == last.new.frac
            })
        {
            return Err(format!(
                "task {task:?}: the live reallocated grant {:?} (round {}) is missing \
                 from the reservation audit log",
                last.new, last.round
            ));
        }
    }
    Ok(())
}

/// Oracle 6: per node, no two records' occupancy windows (pick-up to
/// finish) overlap — the node FIFO must serialize tasks across jobs.
pub fn no_slot_double_booking(records: &[TaskRecord]) -> Result<(), String> {
    let mut per: HashMap<usize, Vec<(Secs, Secs, TaskId)>> = HashMap::new();
    for r in records {
        per.entry(r.node.0).or_default().push((r.picked_at, r.finish, r.task));
    }
    for (node, v) in &mut per {
        v.sort_by(|a, b| (a.0, a.2).cmp(&(b.0, b.2)));
        for w in v.windows(2) {
            if w[1].0 .0 + EPS < w[0].1 .0 {
                return Err(format!(
                    "node {node}: task {:?} picked at {} while task {:?} occupied it until {}",
                    w[1].2, w[1].0, w[0].2, w[0].1
                ));
            }
        }
    }
    Ok(())
}

/// Oracle 8: the stream's last absolute finish respects (a) each job's
/// release time plus its critical-path bound, and (b) the earliest
/// release plus the aggregate best-case work spread over the cluster.
/// Both relaxations assume zero transfer time and no contention, so the
/// real stream can only finish later.
pub fn stream_makespan_lower_bound(
    jobs: &[(Secs, Vec<TaskSpec>)],
    last_finish: f64,
    authorized: &[NodeId],
    node_speed: &[f64],
) -> Result<(), String> {
    if authorized.is_empty() {
        return Ok(());
    }
    let factor = |nd: NodeId| match node_speed.get(nd.0) {
        Some(&f) if f > 0.0 => f,
        _ => 1.0,
    };
    let min_tp = |t: &TaskSpec| {
        authorized.iter().map(|&nd| t.compute.0 * factor(nd)).fold(f64::INFINITY, f64::min)
    };
    let mut total_work = 0.0f64;
    let mut min_submit = f64::INFINITY;
    for (submit, tasks) in jobs {
        if tasks.is_empty() {
            continue;
        }
        let cp = tasks.iter().map(min_tp).fold(0.0f64, f64::max);
        if last_finish + EPS < submit.0 + cp {
            return Err(format!(
                "stream finish {last_finish:.6} beats release {} + critical path {cp:.6}",
                submit.0
            ));
        }
        total_work += tasks.iter().map(min_tp).sum::<f64>();
        min_submit = min_submit.min(submit.0);
    }
    if total_work > 0.0 {
        let bound = min_submit + total_work / authorized.len() as f64;
        if last_finish + EPS < bound {
            return Err(format!(
                "stream finish {last_finish:.6} beats the aggregate work bound {bound:.6}"
            ));
        }
    }
    Ok(())
}

/// Oracles 5-8 over one concurrent stream run.
pub fn check_stream(
    outcome: &StreamOutcome,
    authorized: &[NodeId],
    node_speed: &[f64],
) -> Result<(), String> {
    // 5: per-job exactly-once completion over the job-tagged records
    for j in &outcome.jobs {
        let ids: Vec<TaskId> = j.tasks.iter().map(|t| t.id).collect();
        let recs: Vec<TaskRecord> = outcome
            .records
            .iter()
            .filter(|(job, _)| *job == j.job)
            .map(|(_, r)| r.clone())
            .collect();
        tasks_complete_exactly_once(&ids, &recs)
            .map_err(|e| format!("job {:?} ({}): {e}", j.job, j.name))?;
    }
    let total: usize = outcome.jobs.iter().map(|j| j.tasks.len()).sum();
    if total != outcome.records.len() {
        return Err(format!(
            "{} records for {total} submitted tasks across the stream",
            outcome.records.len()
        ));
    }
    // 6: node FIFO serialization across jobs
    let plain: Vec<TaskRecord> = outcome.records.iter().map(|(_, r)| r.clone()).collect();
    no_slot_double_booking(&plain)?;
    // 7: cross-job per-slot reservation sums on the shared calendar
    reservations_within_capacity(&outcome.reservations)?;
    // 8: stream makespan bounds
    let jobs: Vec<(Secs, Vec<TaskSpec>)> = outcome
        .jobs
        .iter()
        .map(|j| (Secs(j.submitted_at), j.tasks.clone()))
        .collect();
    stream_makespan_lower_bound(&jobs, outcome.last_finish, authorized, node_speed)
}

/// All dynamic-run oracles (1-4 plus 9-11) over one outcome.
pub fn check_dynamics(
    outcome: &DynamicsOutcome,
    tasks: &[TaskSpec],
    authorized: &[NodeId],
    node_speed: &[f64],
) -> Result<(), String> {
    no_task_on_down_node(&outcome.records, &outcome.down_intervals)?;
    tasks_complete_exactly_once(&outcome.submitted, &outcome.records)?;
    reservations_within_capacity(&outcome.reservations)?;
    pulls_from_live_sources(&outcome.pulls, &outcome.down_intervals)?;
    no_leaked_speculation_grants(&outcome.duels)?;
    reallocation_preserves_grant_accounting(&outcome.reallocs, &outcome.reservations)?;
    makespan_lower_bounds(&outcome.records, tasks, authorized, node_speed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::LinkId;

    fn rec(task: usize, node: usize, picked: f64, finish: f64) -> TaskRecord {
        TaskRecord {
            task: TaskId(task),
            node: NodeId(node),
            picked_at: Secs(picked),
            input_ready: Secs(picked),
            compute_start: Secs(picked),
            finish: Secs(finish),
            source: None,
            is_local: true,
            is_map: true,
        }
    }

    #[test]
    fn downtime_overlap_is_flagged() {
        let down = vec![(NodeId(0), Secs(5.0), Secs(10.0))];
        assert!(no_task_on_down_node(&[rec(0, 0, 0.0, 5.0)], &down).is_ok());
        assert!(no_task_on_down_node(&[rec(0, 0, 10.0, 12.0)], &down).is_ok());
        assert!(no_task_on_down_node(&[rec(0, 1, 6.0, 8.0)], &down).is_ok());
        assert!(no_task_on_down_node(&[rec(0, 0, 4.0, 6.0)], &down).is_err());
        assert!(no_task_on_down_node(&[rec(0, 0, 6.0, 7.0)], &down).is_err());
    }

    #[test]
    fn down_sources_are_flagged() {
        let down = vec![(NodeId(1), Secs(5.0), Secs(20.0))];
        let pull = |src: usize, at: f64| PullAudit {
            task: TaskId(0),
            source: NodeId(src),
            at: Secs(at),
        };
        assert!(pulls_from_live_sources(&[pull(0, 10.0)], &down).is_ok());
        assert!(pulls_from_live_sources(&[pull(1, 4.0)], &down).is_ok());
        assert!(pulls_from_live_sources(&[pull(1, 20.0)], &down).is_ok());
        assert!(pulls_from_live_sources(&[pull(1, 5.0)], &down).is_err());
        assert!(pulls_from_live_sources(&[pull(1, 12.0)], &down).is_err());
    }

    #[test]
    fn exactly_once_catches_loss_and_duplication() {
        let sub = vec![TaskId(0), TaskId(1)];
        assert!(tasks_complete_exactly_once(&sub, &[rec(0, 0, 0.0, 1.0), rec(1, 0, 1.0, 2.0)])
            .is_ok());
        assert!(tasks_complete_exactly_once(&sub, &[rec(0, 0, 0.0, 1.0)]).is_err());
        assert!(tasks_complete_exactly_once(
            &sub,
            &[rec(0, 0, 0.0, 1.0), rec(1, 0, 1.0, 2.0), rec(1, 1, 1.0, 2.0)]
        )
        .is_err());
    }

    #[test]
    fn reservation_sweep_catches_oversubscription() {
        let audit = |round: usize, start: usize, n: usize, frac: f64, usable: f64| {
            ReservationAudit {
                round,
                links: vec![LinkId(0)],
                start_slot: start,
                n_slots: n,
                frac,
                usable: vec![usable],
            }
        };
        // two half-rate windows stack to exactly the ceiling: fine
        assert!(reservations_within_capacity(&[
            audit(1, 0, 5, 0.5, 1.0),
            audit(1, 2, 5, 0.5, 1.0)
        ])
        .is_ok());
        // stacked beyond the (degraded) ceiling: flagged
        assert!(reservations_within_capacity(&[
            audit(1, 0, 5, 0.5, 0.6),
            audit(1, 2, 5, 0.5, 0.6)
        ])
        .is_err());
        // different rounds never stack (each round re-reserves afresh)
        assert!(reservations_within_capacity(&[
            audit(1, 0, 5, 0.8, 1.0),
            audit(2, 0, 5, 0.8, 1.0)
        ])
        .is_ok());
    }

    #[test]
    fn leaked_speculation_grants_are_flagged() {
        let duel = |winner: Option<usize>, reserved: bool, released: bool,
                    orig_reserved: bool, orig_released: bool| {
            DuelAudit {
                round: 1,
                task: TaskId(3),
                dup: TaskId(3 + crate::scenario::mitigation::DUP_BASE),
                node: NodeId(1),
                at: Secs(10.0),
                winner: winner.map(TaskId),
                reserved,
                released,
                orig_reserved,
                orig_released,
            }
        };
        let dup = 3 + crate::scenario::mitigation::DUP_BASE;
        // dup won, orig's grant released: fine
        assert!(no_leaked_speculation_grants(&[duel(Some(dup), true, false, true, true)])
            .is_ok());
        // dup won but the killed original kept its grant: flagged
        assert!(no_leaked_speculation_grants(&[duel(Some(dup), true, false, true, false)])
            .is_err());
        // orig won, dup's grant released: fine
        assert!(no_leaked_speculation_grants(&[duel(Some(3), true, true, false, false)])
            .is_ok());
        // orig won but the losing dup kept its grant: flagged
        assert!(no_leaked_speculation_grants(&[duel(Some(3), true, false, false, false)])
            .is_err());
        // crash storm (no winner): both grants must be released
        assert!(no_leaked_speculation_grants(&[duel(None, true, true, true, true)]).is_ok());
        assert!(no_leaked_speculation_grants(&[duel(None, true, true, true, false)])
            .is_err());
        // unreserved attempts can't leak
        assert!(no_leaked_speculation_grants(&[duel(None, false, false, false, false)])
            .is_ok());
    }

    #[test]
    fn realloc_chains_must_be_unbroken_and_end_in_the_audit_log() {
        use crate::sdn::Reservation;
        let resv = |start: usize, frac: f64| Reservation {
            links: vec![LinkId(0), LinkId(1)],
            start_slot: start,
            n_slots: 4,
            frac,
        };
        let row = |at: f64, old: Reservation, new: Reservation| ReallocAudit {
            round: 1,
            task: TaskId(7),
            at: Secs(at),
            old,
            new,
            class_share_mb_s: 5.0,
        };
        let audit_of = |r: &Reservation| ReservationAudit {
            round: 1,
            links: r.links.clone(),
            start_slot: r.start_slot,
            n_slots: r.n_slots,
            frac: r.frac,
            usable: vec![1.0, 1.0],
        };
        // a two-hop chain whose final window is audited: fine
        let chain =
            vec![row(5.0, resv(10, 0.5), resv(14, 0.5)), row(9.0, resv(14, 0.5), resv(12, 0.4))];
        let log = vec![audit_of(&resv(12, 0.4))];
        assert!(reallocation_preserves_grant_accounting(&chain, &log).is_ok());
        // broken chain: the second row renegotiates a window the
        // controller never held after the first
        let broken =
            vec![row(5.0, resv(10, 0.5), resv(14, 0.5)), row(9.0, resv(11, 0.5), resv(12, 0.4))];
        assert!(reallocation_preserves_grant_accounting(&broken, &log).is_err());
        // out of time order: flagged
        let unordered =
            vec![row(9.0, resv(10, 0.5), resv(14, 0.5)), row(5.0, resv(14, 0.5), resv(12, 0.4))];
        assert!(reallocation_preserves_grant_accounting(&unordered, &log).is_err());
        // the live grant vanished from the reservation log: flagged
        let stale_log = vec![audit_of(&resv(14, 0.5))];
        assert!(reallocation_preserves_grant_accounting(&chain, &stale_log).is_err());
        // no reallocations: trivially coherent
        assert!(reallocation_preserves_grant_accounting(&[], &[]).is_ok());
    }

    #[test]
    fn double_booking_is_flagged() {
        // serial on one node: fine
        let ok = vec![rec(0, 0, 0.0, 5.0), rec(1, 0, 5.0, 9.0), rec(2, 1, 1.0, 3.0)];
        assert!(no_slot_double_booking(&ok).is_ok());
        // overlapping windows on one node: flagged
        let bad = vec![rec(0, 0, 0.0, 5.0), rec(1, 0, 4.0, 9.0)];
        assert!(no_slot_double_booking(&bad).is_err());
        // same windows on different nodes: fine
        let split = vec![rec(0, 0, 0.0, 5.0), rec(1, 1, 0.0, 5.0)];
        assert!(no_slot_double_booking(&split).is_ok());
        // zero-width record at a boundary: fine
        let zero = vec![rec(0, 0, 0.0, 5.0), rec(1, 0, 5.0, 5.0), rec(2, 0, 5.0, 8.0)];
        assert!(no_slot_double_booking(&zero).is_ok());
    }

    #[test]
    fn stream_bounds_hold_and_flag_impossible_streams() {
        use crate::hdfs::BlockId;
        let wave = |n: usize| -> Vec<TaskSpec> {
            (0..n).map(|i| TaskSpec::map(i, BlockId(0), 64.0, Secs(10.0), 0.0)).collect()
        };
        let nodes = [NodeId(0), NodeId(1)];
        // two 2-task jobs released at 0 and 100: work bound 20, release
        // bound 110
        let jobs = vec![(Secs(0.0), wave(2)), (Secs(100.0), wave(2))];
        assert!(stream_makespan_lower_bound(&jobs, 110.0, &nodes, &[]).is_ok());
        // beats the second job's release + critical path
        assert!(stream_makespan_lower_bound(&jobs, 105.0, &nodes, &[]).is_err());
        // beats the aggregate work bound: 4 x 10s on 2 nodes from t=0
        let burst = vec![(Secs(0.0), wave(2)), (Secs(0.0), wave(2))];
        assert!(stream_makespan_lower_bound(&burst, 15.0, &nodes, &[]).is_err());
        assert!(stream_makespan_lower_bound(&burst, 20.0, &nodes, &[]).is_ok());
    }

    #[test]
    fn makespan_bounds_hold_and_flag_impossible_runs() {
        use crate::hdfs::BlockId;
        let tasks: Vec<TaskSpec> =
            (0..4).map(|i| TaskSpec::map(i, BlockId(0), 64.0, Secs(10.0), 0.0)).collect();
        let nodes = [NodeId(0), NodeId(1)];
        // 4 x 10s on 2 nodes: work bound 20s, cp bound 10s
        let ok: Vec<TaskRecord> = (0..4)
            .map(|i| rec(i, i % 2, (i / 2) as f64 * 10.0, (i / 2 + 1) as f64 * 10.0))
            .collect();
        assert!(makespan_lower_bounds(&ok, &tasks, &nodes, &[]).is_ok());
        let impossible: Vec<TaskRecord> = (0..4).map(|i| rec(i, i % 2, 0.0, 12.0)).collect();
        assert!(makespan_lower_bounds(&impossible, &tasks, &nodes, &[]).is_err());
    }
}
