//! Deterministic property-testing kit (proptest is not vendored in the
//! offline image — see DESIGN.md toolchain substitutions).
//!
//! [`forall`] drives a property over `iters` generated cases from a
//! seeded [`crate::util::XorShift`]; failures report the case index and
//! sub-seed so any counterexample replays exactly.

pub mod oracles;

use crate::util::XorShift;

/// Run `prop` over `iters` cases drawn by `gen`. On failure, panics with
/// the replayable (seed, case) pair and the case's Debug form.
pub fn forall<T: std::fmt::Debug>(
    seed: u64,
    iters: usize,
    mut gen: impl FnMut(&mut XorShift) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..iters {
        let sub_seed = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(case as u64);
        let mut rng = XorShift::new(sub_seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!("property failed (seed={seed}, case={case}, sub_seed={sub_seed}): {msg}\ninput: {input:#?}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_for_true_property() {
        forall(1, 100, |r| r.below(100), |&x| {
            if x < 100 { Ok(()) } else { Err(format!("{x} out of range")) }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_with_replay_info() {
        forall(2, 50, |r| r.below(10), |&x| {
            if x < 5 { Ok(()) } else { Err("too big".into()) }
        });
    }
}
