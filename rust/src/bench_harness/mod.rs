//! Minimal statistical bench harness (criterion is not vendored in the
//! offline image). Used by `benches/*.rs` with `harness = false`.

use std::time::Instant;

/// Timing statistics over the sample set (seconds per iteration).
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub mean: f64,
    pub p50: f64,
    pub p99: f64,
    pub min: f64,
    pub samples: usize,
}

/// One benchmark runner: warm up, then time `samples` batches.
pub struct Bencher {
    pub warmup_iters: usize,
    pub samples: usize,
    pub iters_per_sample: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self { warmup_iters: 3, samples: 30, iters_per_sample: 1 }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Self { warmup_iters: 1, samples: 10, iters_per_sample: 1 }
    }

    /// Time `f`, returning stats; `f` runs `iters_per_sample` times per
    /// sample and must not be optimized away (return + black_box).
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> Stats {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..self.iters_per_sample {
                std::hint::black_box(f());
            }
            times.push(t0.elapsed().as_secs_f64() / self.iters_per_sample as f64);
        }
        times.sort_by(f64::total_cmp);
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let pct = |p: f64| times[((times.len() as f64 - 1.0) * p).round() as usize];
        Stats { mean, p50: pct(0.5), p99: pct(0.99), min: times[0], samples: times.len() }
    }

    /// Run + print one criterion-style line.
    pub fn bench<T>(&self, name: &str, f: impl FnMut() -> T) -> Stats {
        let s = self.run(f);
        println!(
            "{name:<44} mean {:>10}  p50 {:>10}  p99 {:>10}  min {:>10}",
            fmt_time(s.mean),
            fmt_time(s.p50),
            fmt_time(s.p99),
            fmt_time(s.min)
        );
        s
    }
}

/// Human time formatting (ns/µs/ms/s).
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.1}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.3}s", secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_ordered() {
        let b = Bencher { warmup_iters: 0, samples: 20, iters_per_sample: 1 };
        let s = b.run(|| std::hint::black_box((0..1000).sum::<u64>()));
        assert!(s.min <= s.p50 && s.p50 <= s.p99);
        assert!(s.mean > 0.0);
        assert_eq!(s.samples, 20);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(5e-9).ends_with("ns"));
        assert!(fmt_time(5e-6).ends_with("µs"));
        assert!(fmt_time(5e-3).ends_with("ms"));
        assert!(fmt_time(5.0).ends_with('s'));
    }
}
