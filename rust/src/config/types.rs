//! Typed experiment configuration assembled from the parsed table.

use crate::experiments::{SchedulerKind, Table1Config};
use crate::hdfs::PlacementPolicy;
use crate::scenario::{
    cell_seed, AdmissionPolicy, BackgroundSpec, DynamicsSpec, InitialLoad, MitigationSpec,
    ScenarioSpec, SoakConfig, SpeculationMode, StreamSpec, TenancySpec, TenantClass, TenantSpec,
    TopologyShape, WorkloadSpec,
};
use crate::sdn::{QosPolicy, TelemetrySpec};
use crate::workload::{Diurnal, JobKind, LoadShape, LoadStage, SizeDist};

use super::parser::{parse, Table};

/// What to run (CLI subcommand equivalents).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RunConfig {
    Example1,
    Example3 { background: usize },
    Table1 { kind: JobKind },
    Fig5,
    E2e { jobs: usize },
    /// A user-defined scenario sweep (see `examples/scenario.toml`).
    Scenario,
    /// An online multi-job stream sweep (see `examples/stream.toml`).
    Stream,
    /// The cluster-size scalability sweep (`bass scale`).
    Scale,
    /// The multi-tenant fairness sweep (`bass fairness`).
    Fairness,
    /// The staged-load soak sweep (`bass soak`, see `examples/soak.toml`).
    Soak,
}

/// The `[scale]` run: the scalability sweep as a config file — tree or
/// fat-tree grid, total host counts, shard cap, worker threads.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScaleRun {
    /// `true` = the 8-leaf fat-tree grid; `false` = the 8-switch tree.
    pub fat: bool,
    /// Total host counts per point, each a positive multiple of 8 (the
    /// grids use 8 leaves/switches). Empty = the default grid.
    pub hosts: Vec<usize>,
    /// Cap on the controller's scheduler-state shard count (fat grid
    /// only). Schedule-invariant — only wall times move.
    pub shards: Option<usize>,
    pub threads: usize,
}

impl Default for ScaleRun {
    fn default() -> Self {
        Self { fat: false, hosts: Vec::new(), shards: None, threads: 1 }
    }
}

/// The `[stream]` run: one Poisson job-stream template swept over a set
/// of arrival rates (mean inter-arrival gaps, seconds) for BASS/BAR/HDS.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamRun {
    /// Jobs/sizes/admission/seed template; the per-point mean gap comes
    /// from `rates`.
    pub spec: StreamSpec,
    /// Mean inter-arrival gaps to sweep, sparse to heavy (seconds).
    pub rates: Vec<f64>,
    pub threads: usize,
}

impl Default for StreamRun {
    fn default() -> Self {
        Self { spec: StreamSpec::defaults(), rates: vec![120.0, 30.0, 10.0], threads: 1 }
    }
}

/// The `[load]` run: a shaped arrival trace (ramp / spike / soak /
/// concentrated stages, menu or truncated-Pareto sizes, optional
/// diurnal modulation) played through the bounded-memory soak driver
/// for BASS/BAR/HDS.
#[derive(Debug, Clone, PartialEq)]
pub struct SoakRun {
    /// The staged arrival trace (validated at parse time).
    pub shape: LoadShape,
    /// Trace seed (independent of the scenario seed, so schedulers
    /// compared on one cluster face the identical arrival sequence).
    pub seed: u64,
    /// Admission: maximum concurrently active jobs.
    pub max_active: usize,
    /// Admission: free authorized nodes required to admit.
    pub min_free_slots: usize,
    /// The p95-slowdown SLO the sustained-throughput metric gates on.
    pub target_p95_slowdown: f64,
    /// Exact-sample cap per quantile sketch before centroid merging.
    pub sketch_cap: usize,
    /// SDN calendar compaction period (virtual seconds).
    pub gc_period_secs: f64,
    pub threads: usize,
}

impl SoakRun {
    /// The default staging for `jobs` arrivals at mean gap `gap`: a ramp
    /// in, a burst at 4x the base rate, then a steady soak with the
    /// remainder. Tiny job counts collapse to a single soak stage.
    pub fn staged(jobs: usize, gap: f64) -> Vec<LoadStage> {
        if jobs < 10 {
            return vec![LoadStage::soak(jobs, gap)];
        }
        let ramp = jobs / 5;
        let spike = jobs / 10;
        vec![
            LoadStage::ramp(ramp, 2.0 * gap, gap),
            LoadStage::spike(spike, gap, 4.0),
            LoadStage::soak(jobs - ramp - spike, gap),
        ]
    }

    /// The soak driver's accounting knobs.
    pub fn soak_config(&self) -> SoakConfig {
        SoakConfig {
            target_p95_slowdown: self.target_p95_slowdown,
            sketch_cap: self.sketch_cap,
            gc_period_secs: self.gc_period_secs,
        }
    }

    /// The admission policy the run submits under.
    pub fn policy(&self) -> AdmissionPolicy {
        AdmissionPolicy { max_active: self.max_active, min_free_slots: self.min_free_slots }
    }
}

impl Default for SoakRun {
    fn default() -> Self {
        let shape = LoadShape::new(
            Self::staged(60, 30.0),
            SizeDist::Menu(vec![150.0, 300.0, 600.0]),
            None,
        )
        .expect("default load shape is valid");
        Self {
            shape,
            seed: 2014,
            max_active: usize::MAX,
            min_free_slots: 0,
            target_p95_slowdown: 2.0,
            sketch_cap: 256,
            gc_period_secs: 300.0,
            threads: 1,
        }
    }
}

/// The `[fairness]` run: the multi-tenant stream sweep. Either a
/// `weights` axis (the built-in two-tenant prod/batch contract, sweeping
/// the prod weight) or an explicit `[tenants]` table, crossed with a set
/// of arrival rates for BASS/BAR/HDS.
#[derive(Debug, Clone, PartialEq)]
pub struct FairnessRun {
    /// Prod-tenant DRF weights to sweep (the batch tenant stays at 1).
    /// Ignored when `tenants` is given — the config layer rejects the
    /// combination instead of silently dropping one axis.
    pub weights: Vec<f64>,
    /// Mean inter-arrival gaps to sweep (seconds).
    pub rates: Vec<f64>,
    /// Jobs per stream point.
    pub jobs: usize,
    /// Explicit tenancy from a `[tenants]` table (replaces the built-in
    /// prod/batch pair).
    pub tenants: Option<TenancySpec>,
    pub threads: usize,
}

impl Default for FairnessRun {
    fn default() -> Self {
        Self {
            weights: vec![1.0, 2.0, 4.0],
            rates: vec![60.0, 15.0],
            jobs: 8,
            tenants: None,
            threads: 1,
        }
    }
}

/// A declarative scenario sweep: one base spec expanded over a
/// (size x scheduler) grid. This is what the CLI's `scenario` subcommand
/// runs — arbitrary new workloads without writing a new driver.
#[derive(Debug, Clone)]
pub struct ScenarioSweep {
    pub base: ScenarioSpec,
    pub sizes_mb: Vec<f64>,
    pub schedulers: Vec<SchedulerKind>,
}

impl ScenarioSweep {
    /// Expand the grid: every (size, scheduler) pair becomes a hermetic
    /// spec sharing the base seed (same layout across schedulers).
    pub fn points(&self) -> Vec<ScenarioSpec> {
        let kind = match self.base.workload {
            WorkloadSpec::Job { kind, .. } => kind,
            ref other => panic!("scenario sweeps run Job workloads, got {other:?}"),
        };
        self.sizes_mb
            .iter()
            .flat_map(|&data_mb| {
                self.schedulers.iter().map(move |&sched| {
                    let mut s = self.base.clone();
                    s.workload = WorkloadSpec::Job { kind, data_mb };
                    s.scheduler = sched;
                    s.seed = cell_seed(self.base.seed, data_mb);
                    s
                })
            })
            .collect()
    }

    /// Parse from the TOML-subset table (defaults = the paper's Table I
    /// testbed).
    pub fn from_table(t: &Table) -> anyhow::Result<Self> {
        let kind = match t.get(".job").and_then(|v| v.as_str()).unwrap_or("wordcount") {
            "sort" => JobKind::Sort,
            _ => JobKind::Wordcount,
        };
        let link_mbps =
            t.get("cluster.link_mbps").and_then(|v| v.as_f64()).unwrap_or(100.0);
        let topology = match t.get("cluster.topology").and_then(|v| v.as_str()) {
            Some("fig2") => TopologyShape::Fig2 { link_mbps },
            Some("tree") | None => TopologyShape::Tree {
                switches: t
                    .get("cluster.switches")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(2),
                hosts_per_switch: t
                    .get("cluster.hosts_per_switch")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(3),
                edge_mbps: link_mbps,
                uplink_mbps: t
                    .get("cluster.uplink_mbps")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(link_mbps),
            },
            Some(other) => anyhow::bail!("unknown cluster.topology {other:?}"),
        };
        let name = t
            .get(".name")
            .and_then(|v| v.as_str())
            .unwrap_or("scenario")
            .to_string();
        let mut base =
            ScenarioSpec::new(name, topology, WorkloadSpec::Job { kind, data_mb: 0.0 });
        if let Some(v) = t.get("cluster.replication").and_then(|v| v.as_usize()) {
            base.replication = v;
        }
        base.placement = match t.get("cluster.placement").and_then(|v| v.as_str()) {
            Some("round_robin") => PlacementPolicy::RoundRobin,
            Some("random") | Some("random_distinct") | None => PlacementPolicy::RandomDistinct,
            Some(other) => anyhow::bail!("unknown cluster.placement {other:?}"),
        };
        // the [hdfs] table (strict) overrides the legacy cluster.* keys
        if t.keys().any(|k| k.starts_with("hdfs.")) {
            let h = parse_hdfs(t)?;
            h.apply(&mut base);
        }
        if let Some(v) = t.get("sdn.slot_secs").and_then(|v| v.as_f64()) {
            anyhow::ensure!(v > 0.0, "sdn.slot_secs must be positive");
            base.slot_secs = v;
        }
        base.qos = match t.get("sdn.qos").and_then(|v| v.as_str()) {
            Some("example3") => Some(QosPolicy::example3()),
            Some("shared") | None => None,
            Some(other) => anyhow::bail!("unknown sdn.qos {other:?}"),
        };
        base.background = BackgroundSpec {
            flows: t.get("background.flows").and_then(|v| v.as_usize()).unwrap_or(3),
            rate_mb_s: t
                .get("background.rate_mb_s")
                .and_then(|v| v.as_f64())
                .unwrap_or(3.0),
        };
        base.initial = InitialLoad::Sampled {
            max_secs: t
                .get("background.max_initial_idle")
                .and_then(|v| v.as_f64())
                .unwrap_or(25.0),
        };
        if let Some(v) = t.get("sweep.seed").and_then(|v| v.as_usize()) {
            base.seed = v as u64;
        }
        if let Some(v) = t.get("sweep.reduces").and_then(|v| v.as_usize()) {
            base.reduces = v;
        }
        if let Some(v) = t.get("sweep.slowstart").and_then(|v| v.as_f64()) {
            base.slowstart = v;
        }
        if let Some(v) = t.get(".threads").and_then(|v| v.as_usize()) {
            base.threads = v.max(1);
        }
        if t.keys().any(|k| k.starts_with("dynamics.")) {
            base.dynamics = Some(parse_dynamics(t)?);
        }
        if t.keys().any(|k| k.starts_with("mitigation.")) {
            base.mitigation = Some(parse_mitigation(t)?);
        }
        if t.keys().any(|k| k.starts_with("telemetry.")) {
            base.telemetry = Some(parse_telemetry(t)?);
        }
        if t.keys().any(|k| k.starts_with("tenants.")) {
            base.tenants = Some(parse_tenants(t)?);
        }
        let sizes_mb = t
            .get("sweep.sizes_mb")
            .and_then(|v| v.as_nums())
            .map(|v| v.to_vec())
            .unwrap_or_else(|| vec![150.0, 300.0, 600.0]);
        let schedulers = match t.get("sweep.schedulers").and_then(|v| v.as_str()) {
            None => vec![SchedulerKind::Bass, SchedulerKind::Hds],
            // a typo must not silently run a different scheduler set
            Some(list) => list
                .split(',')
                .map(|s| {
                    SchedulerKind::parse(s.trim())
                        .ok_or_else(|| anyhow::anyhow!("unknown sweep scheduler {:?}", s.trim()))
                })
                .collect::<anyhow::Result<Vec<_>>>()?,
        };
        anyhow::ensure!(!schedulers.is_empty(), "sweep.schedulers is empty");
        Ok(Self { base, sizes_mb, schedulers })
    }
}

/// Full experiment file: run selector + sweep overrides.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub run: RunConfig,
    pub table1: Table1Config,
    /// Present when `run = "scenario"`.
    pub scenario: Option<ScenarioSweep>,
    /// Present when a `[stream]` table was given (used by `run = "stream"`).
    pub stream: Option<StreamRun>,
    /// Present when `run = "scale"`.
    pub scale: Option<ScaleRun>,
    /// Present when `run = "fairness"`.
    pub fairness: Option<FairnessRun>,
    /// Present when `run = "soak"`.
    pub soak: Option<SoakRun>,
}

impl ExperimentConfig {
    /// Defaults: Example 1 + the paper's Table I(a) configuration.
    pub fn default_wordcount() -> Self {
        Self {
            run: RunConfig::Example1,
            table1: Table1Config::paper(JobKind::Wordcount),
            scenario: None,
            stream: None,
            scale: None,
            fairness: None,
            soak: None,
        }
    }

    /// Load from a TOML-subset file (see `examples/experiment.toml`).
    pub fn from_str(text: &str) -> anyhow::Result<Self> {
        let t = parse(text)?;
        let kind = match t.get(".job").and_then(|v| v.as_str()).unwrap_or("wordcount") {
            "sort" => JobKind::Sort,
            _ => JobKind::Wordcount,
        };
        let mut cfg = Table1Config::paper(kind);
        apply_table1(&mut cfg, &t);
        let mut scenario = None;
        // strict parse whenever the table exists: a `[stream]` / `[hdfs]`
        // typo must not silently run a different setup than the user
        // wrote down
        let stream = if t.keys().any(|k| k.starts_with("stream.")) {
            Some(parse_stream(&t)?)
        } else {
            None
        };
        let run = match t.get(".run").and_then(|v| v.as_str()).unwrap_or("example1") {
            "example3" => RunConfig::Example3 {
                background: t
                    .get("example3.background")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(5),
            },
            "table1" => RunConfig::Table1 { kind },
            "fig5" => RunConfig::Fig5,
            "e2e" => RunConfig::E2e {
                jobs: t.get("e2e.jobs").and_then(|v| v.as_usize()).unwrap_or(10),
            },
            "scenario" => {
                scenario = Some(ScenarioSweep::from_table(&t)?);
                RunConfig::Scenario
            }
            "stream" => RunConfig::Stream,
            "scale" => RunConfig::Scale,
            "fairness" => RunConfig::Fairness,
            "soak" => RunConfig::Soak,
            _ => RunConfig::Example1,
        };
        // [scale] mirrors the [hdfs] cross-run contract: the table may
        // only appear where its knobs are honored
        let scale = if t.keys().any(|k| k.starts_with("scale.")) {
            anyhow::ensure!(
                run == RunConfig::Scale,
                "[scale] requires run = \"scale\" ({run:?} would ignore it)"
            );
            Some(parse_scale(&t)?)
        } else if run == RunConfig::Scale {
            // a bare `run = "scale"` gets the default sweep
            Some(ScaleRun::default())
        } else {
            None
        };
        // [fairness] mirrors the [scale] cross-run contract
        let mut fairness = if t.keys().any(|k| k.starts_with("fairness.")) {
            anyhow::ensure!(
                run == RunConfig::Fairness,
                "[fairness] requires run = \"fairness\" ({run:?} would ignore it)"
            );
            Some(parse_fairness(&t)?)
        } else if run == RunConfig::Fairness {
            // a bare `run = "fairness"` gets the default sweep
            Some(FairnessRun::default())
        } else {
            None
        };
        // [load] mirrors the [scale]/[fairness] cross-run contract
        let soak = if t.keys().any(|k| k.starts_with("load.")) {
            anyhow::ensure!(
                run == RunConfig::Soak,
                "[load] requires run = \"soak\" ({run:?} would ignore it)"
            );
            Some(parse_load(&t)?)
        } else if run == RunConfig::Soak {
            // a bare `run = "soak"` gets the default staged shape
            Some(SoakRun::default())
        } else {
            None
        };
        // [tenants] is honored where the tenancy actually reaches a
        // stream driver: scenario specs carry it, the fairness run sweeps
        // it; anywhere else it would be validated and silently dropped —
        // exactly the divergence the strict tables exist to prevent
        if t.keys().any(|k| k.starts_with("tenants.")) {
            match run {
                RunConfig::Scenario => {} // applied by ScenarioSweep::from_table
                RunConfig::Fairness => {
                    anyhow::ensure!(
                        t.get("fairness.weights").is_none(),
                        "[tenants] replaces the fairness.weights axis; give one or the other"
                    );
                    let f = fairness.as_mut().expect("fairness run carries its sweep");
                    f.tenants = Some(parse_tenants(&t)?);
                }
                ref other => anyhow::bail!(
                    "[tenants] applies to scenario/fairness runs; {other:?} would ignore it"
                ),
            }
        }
        // the [hdfs] table may only appear where its knobs are actually
        // honored: scenario runs take everything, table1 takes the
        // replication factor; anywhere else a key would be validated and
        // then silently dropped — exactly the divergence the strict
        // tables exist to prevent, so it errors instead
        if t.keys().any(|k| k.starts_with("hdfs.")) {
            let h = parse_hdfs(&t)?;
            match run {
                RunConfig::Scenario => {} // applied by ScenarioSweep::from_table
                RunConfig::Table1 { .. } => {
                    anyhow::ensure!(
                        h.placement.is_none() && h.bw_aware_sources.is_none(),
                        "[hdfs] placement/selection apply to scenario runs only \
                         (table1 honors hdfs.replication)"
                    );
                    if let Some(r) = h.replication {
                        cfg.replication = r;
                    }
                }
                ref other => anyhow::bail!(
                    "[hdfs] applies to scenario/table1 runs; {other:?} would ignore it"
                ),
            }
        }
        let mut stream = match (&run, stream) {
            // a bare `run = "stream"` gets the default sweep
            (RunConfig::Stream, None) => Some(StreamRun::default()),
            (_, s) => s,
        };
        if let Some(s) = &mut stream {
            if let Some(v) = t.get(".threads").and_then(|v| v.as_usize()) {
                s.threads = v.max(1);
            }
        }
        let mut scale = scale;
        if let Some(s) = &mut scale {
            if let Some(v) = t.get(".threads").and_then(|v| v.as_usize()) {
                s.threads = v.max(1);
            }
        }
        if let Some(f) = &mut fairness {
            if let Some(v) = t.get(".threads").and_then(|v| v.as_usize()) {
                f.threads = v.max(1);
            }
        }
        let mut soak = soak;
        if let Some(s) = &mut soak {
            if let Some(v) = t.get(".threads").and_then(|v| v.as_usize()) {
                s.threads = v.max(1);
            }
        }
        Ok(Self { run, table1: cfg, scenario, stream, scale, fairness, soak })
    }
}

/// Parsed `[hdfs]` table: the data-layer knobs a scenario applies on top
/// of its defaults.
#[derive(Debug, Clone)]
struct HdfsTable {
    replication: Option<usize>,
    placement: Option<PlacementPolicy>,
    bw_aware_sources: Option<bool>,
}

impl HdfsTable {
    fn apply(&self, base: &mut ScenarioSpec) {
        if let Some(r) = self.replication {
            base.replication = r;
        }
        if let Some(p) = &self.placement {
            base.placement = p.clone();
        }
        if let Some(b) = self.bw_aware_sources {
            base.bw_aware_sources = b;
        }
    }
}

/// Parse an `[hdfs]` table, rejecting unknown keys and unsafe shapes
/// (mirrors the `[dynamics]`/`[stream]` contract: a typo'd knob must
/// error, not silently run a different data layer).
fn parse_hdfs(t: &Table) -> anyhow::Result<HdfsTable> {
    const KNOWN: [&str; 5] = [
        "hdfs.replication",
        "hdfs.placement",
        "hdfs.selection",
        "hdfs.hotspot_nodes",
        "hdfs.hotspot_bias",
    ];
    for k in t.keys().filter(|k| k.starts_with("hdfs.")) {
        anyhow::ensure!(
            k == "hdfs." || KNOWN.contains(&k.as_str()),
            "unknown [hdfs] key {k:?}"
        );
    }
    let replication = match t.get("hdfs.replication") {
        None => None,
        Some(v) => match v.as_usize() {
            // dfs.replication = 0 (or a float / string) must not parse
            Some(r) if r >= 1 && r <= 512 => Some(r),
            _ => anyhow::bail!("hdfs.replication must be an integer in [1, 512]"),
        },
    };
    let mut placement = match t.get("hdfs.placement") {
        None => None,
        Some(v) => match v.as_str().and_then(PlacementPolicy::parse) {
            Some(p) => Some(p),
            None => anyhow::bail!(
                "unknown hdfs.placement (expected random | round_robin | rack_aware | hotspot)"
            ),
        },
    };
    let hotspot_nodes = match t.get("hdfs.hotspot_nodes") {
        None => None,
        Some(v) => match v.as_usize() {
            Some(h) if h >= 1 => Some(h),
            _ => anyhow::bail!("hdfs.hotspot_nodes must be a positive integer"),
        },
    };
    let hotspot_bias = match t.get("hdfs.hotspot_bias") {
        None => None,
        Some(v) => match v.as_f64() {
            Some(b) if (0.0..=1.0).contains(&b) => Some(b),
            _ => anyhow::bail!("hdfs.hotspot_bias must be in [0, 1]"),
        },
    };
    match &mut placement {
        Some(PlacementPolicy::Hotspot { hot, bias }) => {
            if let Some(h) = hotspot_nodes {
                *hot = h;
            }
            if let Some(b) = hotspot_bias {
                *bias = b;
            }
        }
        _ => anyhow::ensure!(
            hotspot_nodes.is_none() && hotspot_bias.is_none(),
            "hdfs.hotspot_* knobs require placement = \"hotspot\""
        ),
    }
    let bw_aware_sources = match t.get("hdfs.selection") {
        None => None,
        Some(v) => match v.as_str() {
            Some("bandwidth") => Some(true),
            Some("min_idle") => Some(false),
            _ => anyhow::bail!("hdfs.selection must be \"bandwidth\" or \"min_idle\""),
        },
    };
    Ok(HdfsTable { replication, placement, bw_aware_sources })
}

/// Parse a `[stream]` table onto [`StreamRun::default`], rejecting
/// unknown keys and unsafe shapes (mirrors the `[dynamics]` contract: a
/// typo'd knob must error, not silently run a different stream).
fn parse_stream(t: &Table) -> anyhow::Result<StreamRun> {
    const KNOWN: [&str; 6] = [
        "stream.jobs",
        "stream.rates",
        "stream.sizes_mb",
        "stream.max_active",
        "stream.min_free_slots",
        "stream.seed",
    ];
    for k in t.keys().filter(|k| k.starts_with("stream.")) {
        anyhow::ensure!(
            k == "stream." || KNOWN.contains(&k.as_str()),
            "unknown [stream] key {k:?}"
        );
    }
    let usize_of = |k: &str| -> anyhow::Result<Option<usize>> {
        match t.get(k) {
            None => Ok(None),
            Some(v) => match v.as_usize() {
                Some(x) => Ok(Some(x)),
                None => anyhow::bail!("[stream] {k} must be a non-negative integer"),
            },
        }
    };
    let mut s = StreamRun::default();
    if let Some(v) = usize_of("stream.jobs")? {
        anyhow::ensure!(v >= 1, "stream.jobs must be at least 1");
        s.spec.jobs = v;
    }
    if let Some(v) = t.get("stream.rates") {
        let rates = match v.as_nums() {
            Some(r) => r.to_vec(),
            None => anyhow::bail!("[stream] stream.rates must be a number list"),
        };
        anyhow::ensure!(!rates.is_empty(), "stream.rates is empty");
        anyhow::ensure!(
            rates.iter().all(|&r| r > 0.0),
            "stream.rates entries are mean inter-arrival gaps: must be positive"
        );
        s.rates = rates;
    }
    if let Some(v) = t.get("stream.sizes_mb") {
        let sizes = match v.as_nums() {
            Some(x) => x.to_vec(),
            None => anyhow::bail!("[stream] stream.sizes_mb must be a number list"),
        };
        anyhow::ensure!(!sizes.is_empty(), "stream.sizes_mb is empty");
        anyhow::ensure!(
            sizes.iter().all(|&x| x > 0.0),
            "stream.sizes_mb entries must be positive"
        );
        s.spec.sizes_mb = sizes;
    }
    if let Some(v) = usize_of("stream.max_active")? {
        anyhow::ensure!(v >= 1, "stream.max_active must admit at least one job");
        s.spec.max_active = v;
    }
    if let Some(v) = usize_of("stream.min_free_slots")? {
        s.spec.min_free_slots = v;
    }
    if let Some(v) = usize_of("stream.seed")? {
        s.spec.seed = v as u64;
    }
    Ok(s)
}

/// Parse a `[scale]` table onto [`ScaleRun::default`], rejecting unknown
/// keys and unsafe shapes (mirrors the `[dynamics]`/`[hdfs]` contract: a
/// typo'd knob must error, not silently run a different sweep).
fn parse_scale(t: &Table) -> anyhow::Result<ScaleRun> {
    const KNOWN: [&str; 4] = ["scale.fat", "scale.hosts", "scale.shards", "scale.threads"];
    for k in t.keys().filter(|k| k.starts_with("scale.")) {
        anyhow::ensure!(
            k == "scale." || KNOWN.contains(&k.as_str()),
            "unknown [scale] key {k:?}"
        );
    }
    let mut s = ScaleRun::default();
    if let Some(v) = t.get("scale.fat") {
        s.fat = match v.as_bool() {
            Some(b) => b,
            None => anyhow::bail!("scale.fat must be true or false"),
        };
    }
    if let Some(v) = t.get("scale.hosts") {
        let hosts = match v.as_nums() {
            Some(h) => h.to_vec(),
            None => anyhow::bail!("[scale] scale.hosts must be a number list"),
        };
        anyhow::ensure!(!hosts.is_empty(), "scale.hosts is empty");
        let mut out = Vec::with_capacity(hosts.len());
        for h in hosts {
            let n = h as usize;
            anyhow::ensure!(
                n as f64 == h && n >= 8 && n % 8 == 0,
                "scale.hosts entries must be positive multiples of 8 \
                 (the grids use 8 leaves/switches), got {h}"
            );
            out.push(n);
        }
        s.hosts = out;
    }
    if let Some(v) = t.get("scale.shards") {
        match v.as_usize() {
            Some(n) if n >= 1 => s.shards = Some(n),
            _ => anyhow::bail!("scale.shards must be a positive integer"),
        }
    }
    if let Some(v) = t.get("scale.threads") {
        match v.as_usize() {
            Some(n) if n >= 1 => s.threads = n,
            _ => anyhow::bail!("scale.threads must be a positive integer"),
        }
    }
    anyhow::ensure!(
        s.fat || (s.shards.is_none() && s.hosts.is_empty()),
        "scale.hosts/scale.shards apply to the fat-tree grid (set scale.fat = true)"
    );
    Ok(s)
}

/// Parse a `[dynamics]` table onto [`DynamicsSpec::none`] defaults,
/// rejecting unsafe shapes and unknown keys instead of silently
/// clamping or ignoring them (a typo'd knob must not run a different
/// churn profile than the user wrote down).
fn parse_dynamics(t: &Table) -> anyhow::Result<DynamicsSpec> {
    const KNOWN: [&str; 13] = [
        "dynamics.node_failures",
        "dynamics.mttr_secs",
        "dynamics.link_degradations",
        "dynamics.degrade_floor",
        "dynamics.degrade_secs",
        "dynamics.stragglers",
        "dynamics.straggle_factor",
        "dynamics.straggle_secs",
        "dynamics.cross_flows",
        "dynamics.cross_rate_mb_s",
        "dynamics.cross_secs",
        "dynamics.horizon_secs",
        "dynamics.seed",
    ];
    for k in t.keys().filter(|k| k.starts_with("dynamics.")) {
        anyhow::ensure!(
            k == "dynamics." || KNOWN.contains(&k.as_str()),
            "unknown [dynamics] key {k:?}"
        );
    }
    let mut d = DynamicsSpec::none();
    // strict typed getters: a present-but-mistyped value (2.5 failures,
    // a quoted number, a negative seed) errors instead of silently
    // keeping the default
    let usize_of = |k: &str| -> anyhow::Result<Option<usize>> {
        match t.get(k) {
            None => Ok(None),
            Some(v) => match v.as_usize() {
                Some(x) => Ok(Some(x)),
                None => anyhow::bail!("[dynamics] {k} must be a non-negative integer"),
            },
        }
    };
    let f64_of = |k: &str| -> anyhow::Result<Option<f64>> {
        match t.get(k) {
            None => Ok(None),
            Some(v) => match v.as_f64() {
                Some(x) => Ok(Some(x)),
                None => anyhow::bail!("[dynamics] {k} must be a number"),
            },
        }
    };
    if let Some(v) = usize_of("dynamics.node_failures")? {
        d.node_failures = v;
    }
    if let Some(v) = f64_of("dynamics.mttr_secs")? {
        anyhow::ensure!(v > 0.0, "dynamics.mttr_secs must be positive");
        d.mttr_secs = v;
    }
    if let Some(v) = usize_of("dynamics.link_degradations")? {
        d.link_degradations = v;
    }
    if let Some(v) = f64_of("dynamics.degrade_floor")? {
        // the compiler draws factors in [floor, 1); keep the declared
        // range identical to the one actually used (no silent clamping)
        anyhow::ensure!(
            (0.05..=0.95).contains(&v),
            "dynamics.degrade_floor must be in [0.05, 0.95]"
        );
        d.degrade_floor = v;
    }
    if let Some(v) = f64_of("dynamics.degrade_secs")? {
        anyhow::ensure!(v > 0.0, "dynamics.degrade_secs must be positive");
        d.degrade_secs = v;
    }
    if let Some(v) = usize_of("dynamics.stragglers")? {
        d.stragglers = v;
    }
    if let Some(v) = f64_of("dynamics.straggle_factor")? {
        anyhow::ensure!(v >= 1.0, "dynamics.straggle_factor slows nodes: must be >= 1");
        d.straggle_factor = v;
    }
    if let Some(v) = f64_of("dynamics.straggle_secs")? {
        anyhow::ensure!(v > 0.0, "dynamics.straggle_secs must be positive");
        d.straggle_secs = v;
    }
    if let Some(v) = usize_of("dynamics.cross_flows")? {
        d.cross_flows = v;
    }
    if let Some(v) = f64_of("dynamics.cross_rate_mb_s")? {
        anyhow::ensure!(v > 0.0, "dynamics.cross_rate_mb_s must be positive");
        d.cross_rate_mb_s = v;
    }
    if let Some(v) = f64_of("dynamics.cross_secs")? {
        anyhow::ensure!(v > 0.0, "dynamics.cross_secs must be positive");
        d.cross_secs = v;
    }
    if let Some(v) = f64_of("dynamics.horizon_secs")? {
        anyhow::ensure!(v > 0.0, "dynamics.horizon_secs must be positive");
        d.horizon_secs = v;
    }
    if let Some(v) = usize_of("dynamics.seed")? {
        d.seed = v as u64;
    }
    Ok(d)
}

/// Parse a `[mitigation]` table onto [`MitigationSpec::off`] defaults,
/// rejecting unknown keys and unsafe shapes (mirrors the `[dynamics]`
/// contract: a typo'd knob must error, not silently run a different
/// mitigation policy than the user wrote down).
fn parse_mitigation(t: &Table) -> anyhow::Result<MitigationSpec> {
    const KNOWN: [&str; 4] = [
        "mitigation.speculation",
        "mitigation.slow_threshold",
        "mitigation.evict_factor",
        "mitigation.rebalance_period",
    ];
    for k in t.keys().filter(|k| k.starts_with("mitigation.")) {
        anyhow::ensure!(
            k == "mitigation." || KNOWN.contains(&k.as_str()),
            "unknown [mitigation] key {k:?}"
        );
    }
    let mut m = MitigationSpec::off();
    if let Some(v) = t.get("mitigation.speculation") {
        m.speculation = match v.as_str().and_then(SpeculationMode::parse) {
            Some(s) => s,
            None => anyhow::bail!(
                "mitigation.speculation must be \"off\", \"late\" or \"bw_aware\""
            ),
        };
    }
    let f64_of = |k: &str| -> anyhow::Result<Option<f64>> {
        match t.get(k) {
            None => Ok(None),
            Some(v) => match v.as_f64() {
                Some(x) => Ok(Some(x)),
                None => anyhow::bail!("[mitigation] {k} must be a number"),
            },
        }
    };
    if let Some(v) = f64_of("mitigation.slow_threshold")? {
        anyhow::ensure!(
            v >= 1.0,
            "mitigation.slow_threshold is a stretch factor: must be >= 1"
        );
        m.slow_threshold = v;
    }
    if let Some(v) = f64_of("mitigation.evict_factor")? {
        anyhow::ensure!(
            v > 1.0,
            "mitigation.evict_factor must exceed 1 (a healthy node's stretch)"
        );
        m.evict_factor = v;
    }
    if let Some(v) = f64_of("mitigation.rebalance_period")? {
        anyhow::ensure!(v > 0.0, "mitigation.rebalance_period must be positive");
        m.rebalance_period = v;
    }
    Ok(m)
}

/// Parse a `[telemetry]` table onto [`TelemetrySpec::measured`]
/// defaults, rejecting unknown keys and unsafe shapes (mirrors the
/// `[dynamics]`/`[mitigation]` contract: a typo'd knob must error, not
/// silently schedule from a different information model than the user
/// wrote down).
fn parse_telemetry(t: &Table) -> anyhow::Result<TelemetrySpec> {
    const KNOWN: [&str; 6] = [
        "telemetry.probe_period",
        "telemetry.noise",
        "telemetry.alpha",
        "telemetry.stale_secs",
        "telemetry.seed",
        "telemetry.reallocate",
    ];
    for k in t.keys().filter(|k| k.starts_with("telemetry.")) {
        anyhow::ensure!(
            k == "telemetry." || KNOWN.contains(&k.as_str()),
            "unknown [telemetry] key {k:?}"
        );
    }
    let mut s = TelemetrySpec::measured();
    let f64_of = |k: &str| -> anyhow::Result<Option<f64>> {
        match t.get(k) {
            None => Ok(None),
            Some(v) => match v.as_f64() {
                Some(x) => Ok(Some(x)),
                None => anyhow::bail!("[telemetry] {k} must be a number"),
            },
        }
    };
    if let Some(v) = f64_of("telemetry.probe_period")? {
        anyhow::ensure!(v >= 0.0, "telemetry.probe_period must be >= 0 (0 = continuous)");
        s.probe_period = v;
    }
    if let Some(v) = f64_of("telemetry.noise")? {
        anyhow::ensure!(v >= 0.0, "telemetry.noise is a relative sigma: must be >= 0");
        s.noise = v;
    }
    if let Some(v) = f64_of("telemetry.alpha")? {
        anyhow::ensure!(
            v > 0.0 && v <= 1.0,
            "telemetry.alpha is the EWMA gain: must be in (0, 1]"
        );
        s.alpha = v;
    }
    if let Some(v) = f64_of("telemetry.stale_secs")? {
        anyhow::ensure!(v > 0.0, "telemetry.stale_secs must be positive");
        s.stale_secs = v;
    }
    if let Some(v) = t.get("telemetry.seed") {
        s.seed = match v.as_usize() {
            Some(x) => x as u64,
            None => anyhow::bail!("[telemetry] telemetry.seed must be a non-negative integer"),
        };
    }
    if let Some(v) = t.get("telemetry.reallocate") {
        s.reallocate = match v.as_bool() {
            Some(b) => b,
            None => anyhow::bail!("telemetry.reallocate must be true or false"),
        };
    }
    Ok(s)
}

/// Parse a `[tenants]` table into a [`TenancySpec`], rejecting unknown
/// keys and unsafe shapes (mirrors the `[dynamics]` contract: a typo'd
/// knob must error, not silently admit under a different tenancy than
/// the user wrote down).
///
/// Shape: `names = "prod, batch"` declares the tenant order (admission
/// tie-breaks and round-robin attribution follow it), then one optional
/// `[tenants.<name>]` table per declared tenant sets
/// weight / slot_quota / bw_quota / class / deadline_secs. A bare
/// `[tenants]` header is the single default tenant — the attribution-only
/// configuration pinned bit-identical to the FIFO stream path.
fn parse_tenants(t: &Table) -> anyhow::Result<TenancySpec> {
    const KNOWN: [&str; 5] = ["weight", "slot_quota", "bw_quota", "class", "deadline_secs"];
    let names: Vec<String> = match t.get("tenants.names") {
        None => Vec::new(),
        Some(v) => match v.as_str() {
            Some(s) => {
                let mut out: Vec<String> = Vec::new();
                for n in s.split(',') {
                    let n = n.trim();
                    anyhow::ensure!(!n.is_empty(), "tenants.names holds an empty name");
                    anyhow::ensure!(
                        !out.iter().any(|o| o == n),
                        "duplicate tenant name {n:?} in tenants.names"
                    );
                    out.push(n.to_string());
                }
                anyhow::ensure!(!out.is_empty(), "tenants.names is empty");
                out
            }
            None => anyhow::bail!(
                "tenants.names must be a comma-separated string of tenant names"
            ),
        },
    };
    for k in t.keys().filter(|k| k.starts_with("tenants.")) {
        if k == "tenants." || k == "tenants.names" {
            continue;
        }
        let rest = &k["tenants.".len()..];
        let (name, knob) = match rest.split_once('.') {
            Some(p) => p,
            // a bare `tenants.foo = ...` key: neither the declaration nor
            // a per-tenant knob
            None => anyhow::bail!(
                "unknown [tenants] key {k:?} (declare tenants with names = \"a, b\" \
                 and configure them in [tenants.<name>] tables)"
            ),
        };
        anyhow::ensure!(
            names.iter().any(|n| n == name),
            "[tenants.{name}] is not declared in tenants.names"
        );
        // an empty knob is the `[tenants.<name>]` section marker itself
        anyhow::ensure!(
            knob.is_empty() || KNOWN.contains(&knob),
            "unknown [tenants.{name}] key {knob:?}"
        );
    }
    if names.is_empty() {
        return Ok(TenancySpec::single_default());
    }
    let mut tenants = Vec::with_capacity(names.len());
    for name in &names {
        let mut spec = TenantSpec::named(name.clone());
        if let Some(v) = t.get(&format!("tenants.{name}.weight")) {
            match v.as_f64() {
                Some(w) if w > 0.0 => spec.weight = w,
                _ => anyhow::bail!("tenant '{name}': weight must be a positive number"),
            }
        }
        if let Some(v) = t.get(&format!("tenants.{name}.slot_quota")) {
            match v.as_usize() {
                Some(q) if q >= 1 => spec.slot_quota = q,
                _ => anyhow::bail!("tenant '{name}': slot_quota must be a positive integer"),
            }
        }
        if let Some(v) = t.get(&format!("tenants.{name}.bw_quota")) {
            match v.as_f64() {
                Some(q) if q > 0.0 => spec.bw_quota = q,
                _ => anyhow::bail!("tenant '{name}': bw_quota must be a positive number"),
            }
        }
        if let Some(v) = t.get(&format!("tenants.{name}.class")) {
            spec.class = match v.as_str() {
                Some("guaranteed") => TenantClass::Guaranteed,
                Some("spot") => TenantClass::Spot,
                _ => anyhow::bail!(
                    "tenant '{name}': class must be \"guaranteed\" or \"spot\""
                ),
            };
        }
        if let Some(v) = t.get(&format!("tenants.{name}.deadline_secs")) {
            match v.as_f64() {
                Some(d) if d > 0.0 => spec.deadline_secs = Some(d),
                _ => anyhow::bail!(
                    "tenant '{name}': deadline_secs must be a positive number"
                ),
            }
        }
        tenants.push(spec);
    }
    let spec = TenancySpec { tenants };
    if let Err(e) = spec.validate() {
        anyhow::bail!("[tenants]: {e}");
    }
    Ok(spec)
}

/// Parse a `[fairness]` table onto [`FairnessRun::default`], rejecting
/// unknown keys and unsafe shapes (mirrors the `[scale]` contract).
fn parse_fairness(t: &Table) -> anyhow::Result<FairnessRun> {
    const KNOWN: [&str; 4] =
        ["fairness.weights", "fairness.rates", "fairness.jobs", "fairness.threads"];
    for k in t.keys().filter(|k| k.starts_with("fairness.")) {
        anyhow::ensure!(
            k == "fairness." || KNOWN.contains(&k.as_str()),
            "unknown [fairness] key {k:?}"
        );
    }
    let mut f = FairnessRun::default();
    if let Some(v) = t.get("fairness.weights") {
        let weights = match v.as_nums() {
            Some(w) => w.to_vec(),
            None => anyhow::bail!("[fairness] fairness.weights must be a number list"),
        };
        anyhow::ensure!(!weights.is_empty(), "fairness.weights is empty");
        anyhow::ensure!(
            weights.iter().all(|&w| w > 0.0),
            "fairness.weights entries are DRF weights: must be positive"
        );
        f.weights = weights;
    }
    if let Some(v) = t.get("fairness.rates") {
        let rates = match v.as_nums() {
            Some(r) => r.to_vec(),
            None => anyhow::bail!("[fairness] fairness.rates must be a number list"),
        };
        anyhow::ensure!(!rates.is_empty(), "fairness.rates is empty");
        anyhow::ensure!(
            rates.iter().all(|&r| r > 0.0),
            "fairness.rates entries are mean inter-arrival gaps: must be positive"
        );
        f.rates = rates;
    }
    if let Some(v) = t.get("fairness.jobs") {
        match v.as_usize() {
            Some(n) if n >= 1 => f.jobs = n,
            _ => anyhow::bail!("fairness.jobs must be a positive integer"),
        }
    }
    if let Some(v) = t.get("fairness.threads") {
        match v.as_usize() {
            Some(n) if n >= 1 => f.threads = n,
            _ => anyhow::bail!("fairness.threads must be a positive integer"),
        }
    }
    Ok(f)
}

/// Parse a `[load]` table into a [`SoakRun`], rejecting unknown keys and
/// unsafe shapes (mirrors the `[tenants]` contract: a typo'd knob must
/// error, not silently soak a different load than the user wrote down).
///
/// Shape: `stages = "warmup, burst, steady"` declares the stage order,
/// then one `[load.<stage>]` table per declared stage sets
/// shape / jobs / gap_secs / to_gap_secs / factor / within_secs. Without
/// a declaration, top-level `jobs` / `gap_secs` parameterize the default
/// ramp-spike-soak staging ([`SoakRun::staged`]). Sizes come from either
/// a `sizes_mb` menu or the truncated-Pareto `pareto_*` triple — never
/// both — and `diurnal_amplitude` / `diurnal_period_secs` must appear
/// together.
fn parse_load(t: &Table) -> anyhow::Result<SoakRun> {
    const KNOWN: [&str; 16] = [
        "load.stages",
        "load.jobs",
        "load.gap_secs",
        "load.sizes_mb",
        "load.pareto_alpha",
        "load.pareto_min_mb",
        "load.pareto_cap_mb",
        "load.diurnal_amplitude",
        "load.diurnal_period_secs",
        "load.seed",
        "load.max_active",
        "load.min_free_slots",
        "load.target_p95_slowdown",
        "load.sketch_cap",
        "load.gc_period_secs",
        "load.threads",
    ];
    const STAGE_KNOWN: [&str; 6] =
        ["shape", "jobs", "gap_secs", "to_gap_secs", "factor", "within_secs"];
    let names: Vec<String> = match t.get("load.stages") {
        None => Vec::new(),
        Some(v) => match v.as_str() {
            Some(s) => {
                let mut out: Vec<String> = Vec::new();
                for n in s.split(',') {
                    let n = n.trim();
                    anyhow::ensure!(!n.is_empty(), "load.stages holds an empty name");
                    anyhow::ensure!(
                        !n.contains('.'),
                        "stage name {n:?} must not contain a dot"
                    );
                    anyhow::ensure!(
                        !KNOWN.contains(&format!("load.{n}").as_str()),
                        "stage name {n:?} collides with a [load] knob"
                    );
                    anyhow::ensure!(
                        !out.iter().any(|o| o == n),
                        "duplicate stage name {n:?} in load.stages"
                    );
                    out.push(n.to_string());
                }
                anyhow::ensure!(!out.is_empty(), "load.stages is empty");
                out
            }
            None => anyhow::bail!(
                "load.stages must be a comma-separated string of stage names"
            ),
        },
    };
    for k in t.keys().filter(|k| k.starts_with("load.")) {
        if k == "load." || KNOWN.contains(&k.as_str()) {
            continue;
        }
        let rest = &k["load.".len()..];
        let (name, knob) = match rest.split_once('.') {
            Some(p) => p,
            // a bare `load.foo = ...` key: neither a knob nor a stage
            None => anyhow::bail!(
                "unknown [load] key {k:?} (declare stages with stages = \"a, b\" \
                 and configure them in [load.<stage>] tables)"
            ),
        };
        anyhow::ensure!(
            names.iter().any(|n| n == name),
            "[load.{name}] is not declared in load.stages"
        );
        // an empty knob is the `[load.<stage>]` section marker itself
        anyhow::ensure!(
            knob.is_empty() || STAGE_KNOWN.contains(&knob),
            "unknown [load.{name}] key {knob:?}"
        );
    }
    let usize_of = |k: &str| -> anyhow::Result<Option<usize>> {
        match t.get(k) {
            None => Ok(None),
            Some(v) => match v.as_usize() {
                Some(x) => Ok(Some(x)),
                None => anyhow::bail!("[load] {k} must be a non-negative integer"),
            },
        }
    };
    let f64_of = |k: &str| -> anyhow::Result<Option<f64>> {
        match t.get(k) {
            None => Ok(None),
            Some(v) => match v.as_f64() {
                Some(x) => Ok(Some(x)),
                None => anyhow::bail!("[load] {k} must be a number"),
            },
        }
    };
    let stages = if names.is_empty() {
        let jobs = usize_of("load.jobs")?.unwrap_or(60);
        anyhow::ensure!(jobs >= 1, "load.jobs must be at least 1");
        let gap = f64_of("load.gap_secs")?.unwrap_or(30.0);
        anyhow::ensure!(gap > 0.0, "load.gap_secs must be positive");
        SoakRun::staged(jobs, gap)
    } else {
        // explicit stages replace the default staging wholesale: the
        // shorthand knobs would be validated and silently dropped
        anyhow::ensure!(
            t.get("load.jobs").is_none() && t.get("load.gap_secs").is_none(),
            "load.jobs/load.gap_secs parameterize the default staging; \
             with load.stages configure each [load.<stage>] table instead"
        );
        let mut out = Vec::with_capacity(names.len());
        for name in &names {
            let stage_f64 = |knob: &str| -> anyhow::Result<Option<f64>> {
                match t.get(&format!("load.{name}.{knob}")) {
                    None => Ok(None),
                    Some(v) => match v.as_f64() {
                        Some(x) => Ok(Some(x)),
                        None => anyhow::bail!("stage '{name}': {knob} must be a number"),
                    },
                }
            };
            let require = |knob: &str, v: Option<f64>| -> anyhow::Result<f64> {
                v.ok_or_else(|| anyhow::anyhow!("stage '{name}': {knob} is required"))
            };
            let forbid = |knob: &str, v: &Option<f64>, shape: &str| -> anyhow::Result<()> {
                anyhow::ensure!(
                    v.is_none(),
                    "stage '{name}': {knob} applies to {shape} stages only"
                );
                Ok(())
            };
            let jobs = match t.get(&format!("load.{name}.jobs")) {
                Some(v) => match v.as_usize() {
                    Some(j) if j >= 1 => j,
                    _ => anyhow::bail!("stage '{name}': jobs must be a positive integer"),
                },
                None => anyhow::bail!("stage '{name}': jobs is required"),
            };
            let shape = match t.get(&format!("load.{name}.shape")) {
                None => "soak",
                Some(v) => match v.as_str() {
                    Some(s) => s,
                    None => anyhow::bail!(
                        "stage '{name}': shape must be \"soak\", \"ramp\", \"spike\" \
                         or \"concentrated\""
                    ),
                },
            };
            let gap = stage_f64("gap_secs")?;
            let to_gap = stage_f64("to_gap_secs")?;
            let factor = stage_f64("factor")?;
            let within = stage_f64("within_secs")?;
            out.push(match shape {
                "soak" => {
                    forbid("to_gap_secs", &to_gap, "ramp")?;
                    forbid("factor", &factor, "spike")?;
                    forbid("within_secs", &within, "concentrated")?;
                    LoadStage::soak(jobs, require("gap_secs", gap)?)
                }
                "ramp" => {
                    forbid("factor", &factor, "spike")?;
                    forbid("within_secs", &within, "concentrated")?;
                    LoadStage::ramp(
                        jobs,
                        require("gap_secs", gap)?,
                        require("to_gap_secs", to_gap)?,
                    )
                }
                "spike" => {
                    forbid("to_gap_secs", &to_gap, "ramp")?;
                    forbid("within_secs", &within, "concentrated")?;
                    LoadStage::spike(jobs, require("gap_secs", gap)?, require("factor", factor)?)
                }
                "concentrated" => {
                    forbid("gap_secs", &gap, "soak/ramp/spike")?;
                    forbid("to_gap_secs", &to_gap, "ramp")?;
                    forbid("factor", &factor, "spike")?;
                    LoadStage::concentrated(jobs, require("within_secs", within)?)
                }
                other => anyhow::bail!(
                    "stage '{name}': unknown shape {other:?} (expected soak | ramp | \
                     spike | concentrated)"
                ),
            });
        }
        out
    };
    let n_pareto = ["load.pareto_alpha", "load.pareto_min_mb", "load.pareto_cap_mb"]
        .iter()
        .filter(|k| t.get(k).is_some())
        .count();
    let sizes = if let Some(v) = t.get("load.sizes_mb") {
        anyhow::ensure!(
            n_pareto == 0,
            "load.sizes_mb and load.pareto_* are mutually exclusive size models"
        );
        let sizes = match v.as_nums() {
            Some(x) => x.to_vec(),
            None => anyhow::bail!("[load] load.sizes_mb must be a number list"),
        };
        SizeDist::Menu(sizes)
    } else if n_pareto > 0 {
        anyhow::ensure!(
            n_pareto == 3,
            "the Pareto size model needs all of load.pareto_alpha, \
             load.pareto_min_mb and load.pareto_cap_mb"
        );
        SizeDist::Pareto {
            alpha: f64_of("load.pareto_alpha")?.expect("checked present"),
            min_mb: f64_of("load.pareto_min_mb")?.expect("checked present"),
            cap_mb: f64_of("load.pareto_cap_mb")?.expect("checked present"),
        }
    } else {
        SizeDist::Menu(vec![150.0, 300.0, 600.0])
    };
    let diurnal = match (
        f64_of("load.diurnal_amplitude")?,
        f64_of("load.diurnal_period_secs")?,
    ) {
        (None, None) => None,
        (Some(amplitude), Some(period_secs)) => Some(Diurnal { amplitude, period_secs }),
        _ => anyhow::bail!(
            "diurnal modulation needs both load.diurnal_amplitude and \
             load.diurnal_period_secs"
        ),
    };
    let mut s = SoakRun::default();
    // range validation (gap positivity, Pareto support, amplitude bounds)
    // lives in the generator's constructor — one authority, no drift
    s.shape = match LoadShape::new(stages, sizes, diurnal) {
        Ok(shape) => shape,
        Err(e) => anyhow::bail!("[load]: {e}"),
    };
    if let Some(v) = usize_of("load.seed")? {
        s.seed = v as u64;
    }
    if let Some(v) = usize_of("load.max_active")? {
        anyhow::ensure!(v >= 1, "load.max_active must admit at least one job");
        s.max_active = v;
    }
    if let Some(v) = usize_of("load.min_free_slots")? {
        s.min_free_slots = v;
    }
    if let Some(v) = f64_of("load.target_p95_slowdown")? {
        anyhow::ensure!(
            v >= 1.0,
            "load.target_p95_slowdown is a slowdown ratio: must be >= 1"
        );
        s.target_p95_slowdown = v;
    }
    if let Some(v) = usize_of("load.sketch_cap")? {
        anyhow::ensure!(v >= 1, "load.sketch_cap must be a positive integer");
        s.sketch_cap = v;
    }
    if let Some(v) = f64_of("load.gc_period_secs")? {
        anyhow::ensure!(v > 0.0, "load.gc_period_secs must be positive");
        s.gc_period_secs = v;
    }
    if let Some(v) = t.get("load.threads") {
        match v.as_usize() {
            Some(n) if n >= 1 => s.threads = n,
            _ => anyhow::bail!("load.threads must be a positive integer"),
        }
    }
    Ok(s)
}

fn apply_table1(cfg: &mut Table1Config, t: &Table) {
    if let Some(v) = t.get("cluster.link_mbps").and_then(|v| v.as_f64()) {
        cfg.link_mbps = v;
    }
    if let Some(v) = t.get("cluster.switches").and_then(|v| v.as_usize()) {
        cfg.n_switches = v;
    }
    if let Some(v) = t.get("cluster.hosts_per_switch").and_then(|v| v.as_usize()) {
        cfg.hosts_per_switch = v;
    }
    if let Some(v) = t.get("cluster.replication").and_then(|v| v.as_usize()) {
        cfg.replication = v;
    }
    if let Some(v) = t.get("sweep.sizes_mb").and_then(|v| v.as_nums()) {
        cfg.sizes_mb = v.to_vec();
    }
    if let Some(v) = t.get("sweep.seed").and_then(|v| v.as_usize()) {
        cfg.seed = v as u64;
    }
    if let Some(v) = t.get(".threads").and_then(|v| v.as_usize()) {
        cfg.threads = v.max(1);
    }
    if let Some(v) = t.get("sweep.schedulers").and_then(|v| v.as_str()) {
        let parsed: Vec<SchedulerKind> =
            v.split(',').filter_map(|s| SchedulerKind::parse(s.trim())).collect();
        if !parsed.is_empty() {
            cfg.schedulers = parsed;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_example1() {
        let c = ExperimentConfig::default_wordcount();
        assert_eq!(c.run, RunConfig::Example1);
    }

    #[test]
    fn file_overrides_apply() {
        let c = ExperimentConfig::from_str(
            r#"
run = "table1"
job = "sort"
threads = 4

[cluster]
link_mbps = 200
switches = 3
hosts_per_switch = 2

[sweep]
sizes_mb = [150, 300]
seed = 99
"#,
        )
        .unwrap();
        assert_eq!(c.run, RunConfig::Table1 { kind: JobKind::Sort });
        assert_eq!(c.table1.link_mbps, 200.0);
        assert_eq!(c.table1.n_switches, 3);
        assert_eq!(c.table1.hosts_per_switch, 2);
        assert_eq!(c.table1.sizes_mb, vec![150.0, 300.0]);
        assert_eq!(c.table1.seed, 99);
        assert_eq!(c.table1.threads, 4);
    }

    #[test]
    fn scheduler_list_parses() {
        let c = ExperimentConfig::from_str("[sweep]\nschedulers = \"bass, hds\"\n").unwrap();
        assert_eq!(c.table1.schedulers.len(), 2);
    }

    #[test]
    fn scenario_file_builds_a_sweep() {
        let c = ExperimentConfig::from_str(
            r#"
run = "scenario"
name = "big-sort"
job = "sort"
threads = 3

[cluster]
topology = "tree"
switches = 4
hosts_per_switch = 4
link_mbps = 100
uplink_mbps = 1000
replication = 2
placement = "round_robin"

[sdn]
slot_secs = 0.5

[background]
flows = 5
rate_mb_s = 2.0
max_initial_idle = 10

[sweep]
sizes_mb = [150, 600]
schedulers = "bass, bar, hds"
seed = 42
"#,
        )
        .unwrap();
        assert_eq!(c.run, RunConfig::Scenario);
        let sweep = c.scenario.expect("scenario sweep");
        assert_eq!(sweep.sizes_mb, vec![150.0, 600.0]);
        assert_eq!(sweep.schedulers.len(), 3);
        assert_eq!(sweep.base.threads, 3);
        assert_eq!(sweep.base.slot_secs, 0.5);
        assert_eq!(sweep.base.replication, 2);
        match sweep.base.topology {
            TopologyShape::Tree { switches, uplink_mbps, .. } => {
                assert_eq!(switches, 4);
                assert_eq!(uplink_mbps, 1000.0);
            }
            ref other => panic!("wrong topology {other:?}"),
        }
        // the grid: 2 sizes x 3 schedulers, layout shared per size
        let pts = sweep.points();
        assert_eq!(pts.len(), 6);
        assert_eq!(pts[0].seed, pts[1].seed);
        assert_ne!(pts[0].seed, pts[3].seed);
    }

    #[test]
    fn dynamics_table_parses_onto_defaults() {
        let c = ExperimentConfig::from_str(
            "run = \"scenario\"\n[dynamics]\nnode_failures = 2\nmttr_secs = 40\n\
             stragglers = 1\nstraggle_factor = 2.5\nseed = 7\n",
        )
        .unwrap();
        let d = c.scenario.unwrap().base.dynamics.expect("dynamics parsed");
        assert_eq!(d.node_failures, 2);
        assert_eq!(d.mttr_secs, 40.0);
        assert_eq!(d.stragglers, 1);
        assert_eq!(d.straggle_factor, 2.5);
        assert_eq!(d.seed, 7);
        // untouched knobs keep the none() defaults
        assert_eq!(d.link_degradations, 0);
        assert_eq!(d.cross_flows, 0);
    }

    #[test]
    fn dynamics_rejects_unsafe_shapes() {
        for bad in [
            "run = \"scenario\"\n[dynamics]\nstraggle_factor = 0.5\n",
            "run = \"scenario\"\n[dynamics]\ndegrade_floor = 1.5\n",
            "run = \"scenario\"\n[dynamics]\nmttr_secs = 0\n",
            "run = \"scenario\"\n[dynamics]\nhorizon_secs = -1\n",
            "run = \"scenario\"\n[dynamics]\nnode_failures = 2.5\n",
            "run = \"scenario\"\n[dynamics]\nmttr_secs = \"40\"\n",
            "run = \"scenario\"\n[dynamics]\ndegrade_secs = 0\n",
        ] {
            assert!(ExperimentConfig::from_str(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn dynamics_rejects_unknown_keys() {
        // a typo must not silently run a different churn profile
        let r = ExperimentConfig::from_str(
            "run = \"scenario\"\n[dynamics]\nnode_failure = 2\n",
        );
        assert!(r.unwrap_err().to_string().contains("node_failure"));
    }

    #[test]
    fn scale_table_parses_strictly() {
        let c = ExperimentConfig::from_str(
            "run = \"scale\"\n[scale]\nfat = true\nhosts = [16, 32]\nshards = 4\nthreads = 2\n",
        )
        .unwrap();
        assert_eq!(c.run, RunConfig::Scale);
        let s = c.scale.unwrap();
        assert!(s.fat);
        assert_eq!(s.hosts, vec![16, 32]);
        assert_eq!(s.shards, Some(4));
        assert_eq!(s.threads, 2);
        // a bare `run = "scale"` gets the default sweep
        let d = ExperimentConfig::from_str("run = \"scale\"\n").unwrap();
        assert_eq!(d.scale, Some(ScaleRun::default()));
    }

    #[test]
    fn scale_rejects_unknown_keys_and_unsafe_shapes() {
        for bad in [
            "run = \"scale\"\n[scale]\nshard = 4\n",                // typo'd key
            "run = \"scale\"\n[scale]\nfat = true\nshards = 0\n",   // non-positive
            "run = \"scale\"\n[scale]\nfat = true\nshards = 2.5\n", // mistyped
            "run = \"scale\"\n[scale]\nfat = true\nthreads = 0\n",  // non-positive
            "run = \"scale\"\n[scale]\nfat = true\nhosts = [12]\n", // not a multiple of 8
            "run = \"scale\"\n[scale]\nfat = true\nhosts = [0]\n",  // non-positive
            "run = \"scale\"\n[scale]\nshards = 4\n",               // shards without fat
            "run = \"scale\"\n[scale]\nhosts = [16]\n",             // hosts without fat
            "run = \"scale\"\n[scale]\nfat = 3\n",                  // mistyped bool
            "run = \"table1\"\n[scale]\nfat = true\n",              // cross-run
        ] {
            assert!(ExperimentConfig::from_str(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn scenario_without_dynamics_table_stays_static() {
        let c = ExperimentConfig::from_str("run = \"scenario\"\n").unwrap();
        assert!(c.scenario.unwrap().base.dynamics.is_none());
    }

    #[test]
    fn bare_dynamics_table_selects_the_churn_route_with_defaults() {
        // a `[dynamics]` header with every knob omitted must not fall
        // back silently to the static route
        let c = ExperimentConfig::from_str("run = \"scenario\"\n[dynamics]\n").unwrap();
        let d = c.scenario.unwrap().base.dynamics.expect("churn route selected");
        assert_eq!(d, DynamicsSpec::none());
    }

    #[test]
    fn mitigation_table_parses_onto_off_defaults() {
        let c = ExperimentConfig::from_str(
            "run = \"scenario\"\n[mitigation]\nspeculation = \"bw_aware\"\n\
             slow_threshold = 1.8\nevict_factor = 4.0\nrebalance_period = 30\n",
        )
        .unwrap();
        let m = c.scenario.unwrap().base.mitigation.expect("mitigation parsed");
        assert_eq!(m.speculation, SpeculationMode::BwAware);
        assert_eq!(m.slow_threshold, 1.8);
        assert_eq!(m.evict_factor, 4.0);
        assert_eq!(m.rebalance_period, 30.0);
        // untouched knobs keep the off() defaults
        let c = ExperimentConfig::from_str(
            "run = \"scenario\"\n[mitigation]\nspeculation = \"late\"\n",
        )
        .unwrap();
        let m = c.scenario.unwrap().base.mitigation.unwrap();
        assert_eq!(m.speculation, SpeculationMode::Late);
        assert_eq!(m.slow_threshold, 1.5);
        assert!(m.evict_factor.is_infinite());
        assert_eq!(m.rebalance_period, 0.0);
    }

    #[test]
    fn bare_mitigation_table_is_inert() {
        // a `[mitigation]` header with every knob omitted routes through
        // the mitigation layer but changes nothing (inert = delegate)
        let c = ExperimentConfig::from_str("run = \"scenario\"\n[mitigation]\n").unwrap();
        let m = c.scenario.unwrap().base.mitigation.expect("route selected");
        assert!(m.is_inert());
        // and no table at all leaves the field empty
        let c = ExperimentConfig::from_str("run = \"scenario\"\n").unwrap();
        assert!(c.scenario.unwrap().base.mitigation.is_none());
    }

    #[test]
    fn mitigation_rejects_unknown_keys() {
        // a typo must not silently run a different mitigation policy
        let r = ExperimentConfig::from_str(
            "run = \"scenario\"\n[mitigation]\nspeculate = \"late\"\n",
        );
        assert!(r.unwrap_err().to_string().contains("speculate"));
    }

    #[test]
    fn mitigation_rejects_mistyped_and_unsafe_values() {
        for bad in [
            // unknown / misspelled mode strings
            "run = \"scenario\"\n[mitigation]\nspeculation = \"bw-aware\"\n",
            "run = \"scenario\"\n[mitigation]\nspeculation = \"LATE\"\n",
            "run = \"scenario\"\n[mitigation]\nspeculation = 1\n",
            // out-of-range / mistyped numbers
            "run = \"scenario\"\n[mitigation]\nslow_threshold = 0.5\n",
            "run = \"scenario\"\n[mitigation]\nslow_threshold = \"1.5\"\n",
            "run = \"scenario\"\n[mitigation]\nevict_factor = 1.0\n",
            "run = \"scenario\"\n[mitigation]\nevict_factor = 0\n",
            "run = \"scenario\"\n[mitigation]\nrebalance_period = 0\n",
            "run = \"scenario\"\n[mitigation]\nrebalance_period = -5\n",
        ] {
            assert!(ExperimentConfig::from_str(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn telemetry_table_parses_onto_measured_defaults() {
        let c = ExperimentConfig::from_str(
            "run = \"scenario\"\n[telemetry]\nprobe_period = 2.5\nnoise = 0.2\n\
             alpha = 0.5\nstale_secs = 12\nseed = 9\nreallocate = true\n",
        )
        .unwrap();
        let s = c.scenario.unwrap().base.telemetry.expect("telemetry parsed");
        assert_eq!(s.probe_period, 2.5);
        assert_eq!(s.noise, 0.2);
        assert_eq!(s.alpha, 0.5);
        assert_eq!(s.stale_secs, 12.0);
        assert_eq!(s.seed, 9);
        assert!(s.reallocate);
        // untouched knobs keep the measured() defaults
        let c = ExperimentConfig::from_str(
            "run = \"scenario\"\n[telemetry]\nnoise = 0.1\n",
        )
        .unwrap();
        let s = c.scenario.unwrap().base.telemetry.unwrap();
        assert_eq!(s.probe_period, 5.0);
        assert_eq!(s.alpha, 0.3);
        assert!(!s.reallocate);
    }

    #[test]
    fn absent_telemetry_table_stays_clairvoyant() {
        // no `[telemetry]` = the Oracle view, bit-identical to every
        // pre-telemetry run; a bare header opts into the measured plane
        // with its defaults
        let c = ExperimentConfig::from_str("run = \"scenario\"\n").unwrap();
        assert!(c.scenario.unwrap().base.telemetry.is_none());
        let c = ExperimentConfig::from_str("run = \"scenario\"\n[telemetry]\n").unwrap();
        assert_eq!(
            c.scenario.unwrap().base.telemetry,
            Some(TelemetrySpec::measured())
        );
    }

    #[test]
    fn telemetry_rejects_unknown_keys() {
        // a typo must not silently schedule from a different information
        // model
        let r = ExperimentConfig::from_str(
            "run = \"scenario\"\n[telemetry]\nprobe_secs = 5\n",
        );
        assert!(r.unwrap_err().to_string().contains("probe_secs"));
    }

    #[test]
    fn telemetry_rejects_mistyped_and_unsafe_values() {
        for bad in [
            "run = \"scenario\"\n[telemetry]\nprobe_period = -1\n",
            "run = \"scenario\"\n[telemetry]\nprobe_period = \"5\"\n",
            "run = \"scenario\"\n[telemetry]\nnoise = -0.1\n",
            "run = \"scenario\"\n[telemetry]\nalpha = 0\n",
            "run = \"scenario\"\n[telemetry]\nalpha = 1.5\n",
            "run = \"scenario\"\n[telemetry]\nstale_secs = 0\n",
            "run = \"scenario\"\n[telemetry]\nseed = 1.5\n",
            "run = \"scenario\"\n[telemetry]\nseed = -1\n",
            "run = \"scenario\"\n[telemetry]\nreallocate = 1\n",
        ] {
            assert!(ExperimentConfig::from_str(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn stream_table_parses_onto_defaults() {
        let c = ExperimentConfig::from_str(
            "run = \"stream\"\nthreads = 3\n[stream]\njobs = 20\nrates = [240, 60, 15]\n\
             sizes_mb = [150, 600]\nmax_active = 4\nmin_free_slots = 2\nseed = 99\n",
        )
        .unwrap();
        assert_eq!(c.run, RunConfig::Stream);
        let s = c.stream.expect("stream parsed");
        assert_eq!(s.spec.jobs, 20);
        assert_eq!(s.rates, vec![240.0, 60.0, 15.0]);
        assert_eq!(s.spec.sizes_mb, vec![150.0, 600.0]);
        assert_eq!(s.spec.max_active, 4);
        assert_eq!(s.spec.min_free_slots, 2);
        assert_eq!(s.spec.seed, 99);
        assert_eq!(s.threads, 3);
    }

    #[test]
    fn bare_stream_run_gets_the_default_sweep() {
        let c = ExperimentConfig::from_str("run = \"stream\"\n").unwrap();
        assert_eq!(c.run, RunConfig::Stream);
        assert_eq!(c.stream, Some(StreamRun::default()));
        // untouched knobs keep the defaults
        let s = c.stream.unwrap();
        assert_eq!(s.spec.min_free_slots, 0);
        assert_eq!(s.spec.max_active, usize::MAX);
    }

    #[test]
    fn stream_rejects_unknown_keys() {
        // a typo must not silently run a different stream
        let r = ExperimentConfig::from_str("run = \"stream\"\n[stream]\njob = 20\n");
        assert!(r.unwrap_err().to_string().contains("job"));
        let r = ExperimentConfig::from_str("run = \"stream\"\n[stream]\nrate = [60]\n");
        assert!(r.unwrap_err().to_string().contains("rate"));
    }

    #[test]
    fn stream_rejects_mistyped_and_unsafe_values() {
        for bad in [
            // mistyped
            "run = \"stream\"\n[stream]\njobs = 2.5\n",
            "run = \"stream\"\n[stream]\njobs = \"12\"\n",
            "run = \"stream\"\n[stream]\nrates = 60\n",
            "run = \"stream\"\n[stream]\nsizes_mb = \"150\"\n",
            "run = \"stream\"\n[stream]\nmax_active = -1\n",
            "run = \"stream\"\n[stream]\nseed = 1.5\n",
            // non-positive / empty shapes
            "run = \"stream\"\n[stream]\njobs = 0\n",
            "run = \"stream\"\n[stream]\nrates = []\n",
            "run = \"stream\"\n[stream]\nrates = [60, 0]\n",
            "run = \"stream\"\n[stream]\nrates = [60, -5]\n",
            "run = \"stream\"\n[stream]\nsizes_mb = []\n",
            "run = \"stream\"\n[stream]\nsizes_mb = [150, 0]\n",
            "run = \"stream\"\n[stream]\nmax_active = 0\n",
        ] {
            assert!(ExperimentConfig::from_str(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn stream_table_without_stream_run_still_validates() {
        // the table is checked wherever it appears, so a typo can't hide
        // behind a non-stream run selector
        let r = ExperimentConfig::from_str("run = \"example1\"\n[stream]\nbogus = 1\n");
        assert!(r.is_err());
        let c = ExperimentConfig::from_str("run = \"example1\"\n[stream]\njobs = 4\n").unwrap();
        assert_eq!(c.run, RunConfig::Example1);
        assert_eq!(c.stream.unwrap().spec.jobs, 4);
    }

    #[test]
    fn hdfs_table_parses_onto_the_scenario() {
        let c = ExperimentConfig::from_str(
            "run = \"scenario\"\n[hdfs]\nreplication = 2\nplacement = \"rack_aware\"\n\
             selection = \"min_idle\"\n",
        )
        .unwrap();
        let base = c.scenario.unwrap().base;
        assert_eq!(base.replication, 2);
        assert!(matches!(base.placement, PlacementPolicy::RackAware));
        assert!(!base.bw_aware_sources);
        // defaults stand when the table is absent
        let c = ExperimentConfig::from_str("run = \"scenario\"\n").unwrap();
        let base = c.scenario.unwrap().base;
        assert_eq!(base.replication, 3);
        assert!(base.bw_aware_sources);
    }

    #[test]
    fn hdfs_hotspot_knobs_shape_the_policy() {
        let c = ExperimentConfig::from_str(
            "run = \"scenario\"\n[hdfs]\nplacement = \"hotspot\"\nhotspot_nodes = 3\n\
             hotspot_bias = 0.75\n",
        )
        .unwrap();
        match c.scenario.unwrap().base.placement {
            PlacementPolicy::Hotspot { hot, bias } => {
                assert_eq!(hot, 3);
                assert_eq!(bias, 0.75);
            }
            other => panic!("wrong policy {other:?}"),
        }
    }

    #[test]
    fn hdfs_table_overrides_the_legacy_cluster_keys() {
        let c = ExperimentConfig::from_str(
            "run = \"scenario\"\n[cluster]\nreplication = 3\nplacement = \"round_robin\"\n\
             [hdfs]\nreplication = 1\nplacement = \"random\"\n",
        )
        .unwrap();
        let base = c.scenario.unwrap().base;
        assert_eq!(base.replication, 1);
        assert!(matches!(base.placement, PlacementPolicy::RandomDistinct));
    }

    #[test]
    fn hdfs_rejects_unknown_keys_and_bad_replication() {
        // a typo must not silently run a different data layer
        let r = ExperimentConfig::from_str("run = \"scenario\"\n[hdfs]\nreplicas = 3\n");
        assert!(r.unwrap_err().to_string().contains("replicas"));
        for bad in [
            "run = \"scenario\"\n[hdfs]\nreplication = 0\n",
            "run = \"scenario\"\n[hdfs]\nreplication = 2.5\n",
            "run = \"scenario\"\n[hdfs]\nreplication = \"3\"\n",
            "run = \"scenario\"\n[hdfs]\nreplication = 1000\n",
            "run = \"scenario\"\n[hdfs]\nplacement = \"roundrobin\"\n",
            "run = \"scenario\"\n[hdfs]\nselection = \"idle\"\n",
            "run = \"scenario\"\n[hdfs]\nhotspot_bias = 1.5\n",
            "run = \"scenario\"\n[hdfs]\nhotspot_nodes = 0\n",
            // hotspot knobs without the hotspot policy
            "run = \"scenario\"\n[hdfs]\nplacement = \"random\"\nhotspot_bias = 0.5\n",
            "run = \"scenario\"\n[hdfs]\nhotspot_nodes = 2\n",
        ] {
            assert!(ExperimentConfig::from_str(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn hdfs_table_is_checked_on_non_scenario_runs_too() {
        let r = ExperimentConfig::from_str("run = \"table1\"\n[hdfs]\nbogus = 1\n");
        assert!(r.is_err());
        // the replication factor reaches the Table I config
        let c =
            ExperimentConfig::from_str("run = \"table1\"\n[hdfs]\nreplication = 2\n").unwrap();
        assert_eq!(c.table1.replication, 2);
        // keys a run selector cannot honor must error, never silently
        // drop: table1 ignores placement/selection, stream/example1
        // ignore the whole table
        for bad in [
            "run = \"table1\"\n[hdfs]\nplacement = \"hotspot\"\n",
            "run = \"table1\"\n[hdfs]\nselection = \"min_idle\"\n",
            "run = \"stream\"\n[hdfs]\nreplication = 2\n",
            "run = \"example1\"\n[hdfs]\nreplication = 2\n",
        ] {
            assert!(ExperimentConfig::from_str(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn scenario_rejects_unknown_topology() {
        let r = ExperimentConfig::from_str("run = \"scenario\"\n[cluster]\ntopology = \"mesh\"\n");
        assert!(r.is_err());
    }

    #[test]
    fn scenario_rejects_typos_instead_of_defaulting() {
        // a misspelled scheduler must not silently run the default pair
        let r = ExperimentConfig::from_str(
            "run = \"scenario\"\n[sweep]\nschedulers = \"bass, barr\"\n",
        );
        assert!(r.unwrap_err().to_string().contains("barr"));
        let r = ExperimentConfig::from_str(
            "run = \"scenario\"\n[cluster]\nplacement = \"roundrobin\"\n",
        );
        assert!(r.is_err());
        let r = ExperimentConfig::from_str("run = \"scenario\"\n[sdn]\nqos = \"q1q2\"\n");
        assert!(r.is_err());
        // the documented spellings parse
        let c = ExperimentConfig::from_str(
            "run = \"scenario\"\n[cluster]\nplacement = \"round_robin\"\n[sdn]\nqos = \"example3\"\n",
        )
        .unwrap();
        let sweep = c.scenario.unwrap();
        assert!(matches!(sweep.base.placement, PlacementPolicy::RoundRobin));
        assert!(sweep.base.qos.is_some());
    }

    #[test]
    fn tenants_table_parses_onto_the_scenario() {
        let c = ExperimentConfig::from_str(
            "run = \"scenario\"\n[tenants]\nnames = \"prod, batch\"\n\
             [tenants.prod]\nweight = 2\nclass = \"guaranteed\"\ndeadline_secs = 90\n\
             [tenants.batch]\nslot_quota = 6\nbw_quota = 40\nclass = \"spot\"\n",
        )
        .unwrap();
        let tn = c.scenario.unwrap().base.tenants.expect("tenancy parsed");
        assert_eq!(tn.tenants.len(), 2);
        let prod = &tn.tenants[0];
        assert_eq!(prod.name, "prod");
        assert_eq!(prod.weight, 2.0);
        assert_eq!(prod.class, TenantClass::Guaranteed);
        assert_eq!(prod.deadline_secs, Some(90.0));
        assert_eq!(prod.slot_quota, usize::MAX);
        let batch = &tn.tenants[1];
        assert_eq!(batch.name, "batch");
        assert_eq!(batch.weight, 1.0);
        assert_eq!(batch.slot_quota, 6);
        assert_eq!(batch.bw_quota, 40.0);
        assert_eq!(batch.class, TenantClass::Spot);
        assert_eq!(batch.deadline_secs, None);
    }

    #[test]
    fn bare_tenants_table_is_the_single_default_tenant() {
        // a `[tenants]` header with no declarations opts into the
        // tenancy route in its attribution-only shape (the FIFO pin)
        let c = ExperimentConfig::from_str("run = \"scenario\"\n[tenants]\n").unwrap();
        assert_eq!(
            c.scenario.unwrap().base.tenants,
            Some(TenancySpec::single_default())
        );
        // and no table at all leaves the field empty
        let c = ExperimentConfig::from_str("run = \"scenario\"\n").unwrap();
        assert!(c.scenario.unwrap().base.tenants.is_none());
    }

    #[test]
    fn tenants_rejects_unknown_keys_and_undeclared_tenants() {
        // a typo must not silently admit under a different tenancy
        let r = ExperimentConfig::from_str(
            "run = \"scenario\"\n[tenants]\nnames = \"a\"\n[tenants.a]\nwieght = 2\n",
        );
        assert!(r.unwrap_err().to_string().contains("wieght"));
        // a configured-but-undeclared tenant is a typo, not a new tenant
        let r = ExperimentConfig::from_str(
            "run = \"scenario\"\n[tenants]\nnames = \"a\"\n[tenants.b]\nweight = 2\n",
        );
        assert!(r.unwrap_err().to_string().contains("not declared"));
        // a bare key under [tenants] that is not the declaration
        let r = ExperimentConfig::from_str("run = \"scenario\"\n[tenants]\nname = \"a\"\n");
        assert!(r.is_err());
    }

    #[test]
    fn tenants_rejects_mistyped_and_unsafe_values() {
        for bad in [
            // malformed declarations
            "run = \"scenario\"\n[tenants]\nnames = 3\n",
            "run = \"scenario\"\n[tenants]\nnames = \"\"\n",
            "run = \"scenario\"\n[tenants]\nnames = \"a,,b\"\n",
            "run = \"scenario\"\n[tenants]\nnames = \"a, a\"\n", // duplicate
            // non-positive / mistyped knobs
            "run = \"scenario\"\n[tenants]\nnames = \"a\"\n[tenants.a]\nweight = 0\n",
            "run = \"scenario\"\n[tenants]\nnames = \"a\"\n[tenants.a]\nweight = -2\n",
            "run = \"scenario\"\n[tenants]\nnames = \"a\"\n[tenants.a]\nweight = \"2\"\n",
            "run = \"scenario\"\n[tenants]\nnames = \"a\"\n[tenants.a]\nslot_quota = 0\n",
            "run = \"scenario\"\n[tenants]\nnames = \"a\"\n[tenants.a]\nslot_quota = 2.5\n",
            "run = \"scenario\"\n[tenants]\nnames = \"a\"\n[tenants.a]\nbw_quota = 0\n",
            "run = \"scenario\"\n[tenants]\nnames = \"a\"\n[tenants.a]\nclass = \"premium\"\n",
            "run = \"scenario\"\n[tenants]\nnames = \"a\"\n[tenants.a]\nclass = 1\n",
            "run = \"scenario\"\n[tenants]\nnames = \"a\"\n[tenants.a]\ndeadline_secs = 0\n",
            "run = \"scenario\"\n[tenants]\nnames = \"a\"\n[tenants.a]\ndeadline_secs = -5\n",
        ] {
            assert!(ExperimentConfig::from_str(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn tenants_table_is_rejected_on_runs_that_ignore_it() {
        // same contract as [hdfs]: validated-then-dropped is exactly the
        // divergence the strict tables exist to prevent
        for bad in [
            "run = \"stream\"\n[tenants]\nnames = \"a\"\n",
            "run = \"example1\"\n[tenants]\n",
            "run = \"table1\"\n[tenants]\nnames = \"a\"\n",
        ] {
            assert!(ExperimentConfig::from_str(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn fairness_run_parses_and_defaults() {
        let c = ExperimentConfig::from_str(
            "run = \"fairness\"\n[fairness]\nweights = [1, 3]\nrates = [40]\n\
             jobs = 6\nthreads = 2\n",
        )
        .unwrap();
        assert_eq!(c.run, RunConfig::Fairness);
        let f = c.fairness.expect("fairness parsed");
        assert_eq!(f.weights, vec![1.0, 3.0]);
        assert_eq!(f.rates, vec![40.0]);
        assert_eq!(f.jobs, 6);
        assert_eq!(f.threads, 2);
        assert!(f.tenants.is_none());
        // a bare `run = "fairness"` gets the default sweep
        let d = ExperimentConfig::from_str("run = \"fairness\"\n").unwrap();
        assert_eq!(d.fairness, Some(FairnessRun::default()));
    }

    #[test]
    fn fairness_run_takes_an_explicit_tenancy() {
        let c = ExperimentConfig::from_str(
            "run = \"fairness\"\n[fairness]\nrates = [40]\njobs = 4\n\
             [tenants]\nnames = \"gold, silver\"\n[tenants.gold]\nweight = 3\n",
        )
        .unwrap();
        let f = c.fairness.unwrap();
        let tn = f.tenants.expect("explicit tenancy");
        assert_eq!(tn.tenants[0].name, "gold");
        assert_eq!(tn.tenants[0].weight, 3.0);
        // weights axis and explicit tenancy together are ambiguous
        let r = ExperimentConfig::from_str(
            "run = \"fairness\"\n[fairness]\nweights = [1, 2]\n\
             [tenants]\nnames = \"a, b\"\n",
        );
        assert!(r.unwrap_err().to_string().contains("weights"));
    }

    #[test]
    fn fairness_rejects_unknown_keys_unsafe_shapes_and_cross_run_use() {
        let r = ExperimentConfig::from_str("run = \"fairness\"\n[fairness]\nweight = [2]\n");
        assert!(r.unwrap_err().to_string().contains("weight"));
        for bad in [
            "run = \"fairness\"\n[fairness]\nweights = []\n",
            "run = \"fairness\"\n[fairness]\nweights = [0]\n",
            "run = \"fairness\"\n[fairness]\nweights = [-1]\n",
            "run = \"fairness\"\n[fairness]\nweights = 2\n",
            "run = \"fairness\"\n[fairness]\nrates = []\n",
            "run = \"fairness\"\n[fairness]\nrates = [0]\n",
            "run = \"fairness\"\n[fairness]\njobs = 0\n",
            "run = \"fairness\"\n[fairness]\njobs = 2.5\n",
            "run = \"fairness\"\n[fairness]\nthreads = 0\n",
            "run = \"table1\"\n[fairness]\njobs = 4\n", // cross-run
        ] {
            assert!(ExperimentConfig::from_str(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn load_run_parses_staged_shape_and_driver_knobs() {
        let c = ExperimentConfig::from_str(
            "run = \"soak\"\nthreads = 2\n[load]\nstages = \"warmup, burst, steady\"\n\
             pareto_alpha = 1.5\npareto_min_mb = 100\npareto_cap_mb = 600\n\
             diurnal_amplitude = 0.3\ndiurnal_period_secs = 86400\n\
             seed = 77\nmax_active = 6\nmin_free_slots = 1\n\
             target_p95_slowdown = 3\nsketch_cap = 64\ngc_period_secs = 120\n\
             [load.warmup]\nshape = \"ramp\"\njobs = 10\ngap_secs = 60\nto_gap_secs = 20\n\
             [load.burst]\nshape = \"spike\"\njobs = 5\ngap_secs = 20\nfactor = 4\n\
             [load.steady]\njobs = 25\ngap_secs = 30\n",
        )
        .unwrap();
        assert_eq!(c.run, RunConfig::Soak);
        let s = c.soak.expect("soak parsed");
        let expected = LoadShape::new(
            vec![
                LoadStage::ramp(10, 60.0, 20.0),
                LoadStage::spike(5, 20.0, 4.0),
                LoadStage::soak(25, 30.0), // shape defaults to soak
            ],
            SizeDist::Pareto { alpha: 1.5, min_mb: 100.0, cap_mb: 600.0 },
            Some(Diurnal { amplitude: 0.3, period_secs: 86400.0 }),
        )
        .unwrap();
        assert_eq!(s.shape, expected);
        assert_eq!(s.seed, 77);
        assert_eq!(s.threads, 2);
        // the run's accounting/admission views mirror its knobs
        let cfg = s.soak_config();
        assert_eq!(cfg.target_p95_slowdown, 3.0);
        assert_eq!(cfg.sketch_cap, 64);
        assert_eq!(cfg.gc_period_secs, 120.0);
        let p = s.policy();
        assert_eq!(p.max_active, 6);
        assert_eq!(p.min_free_slots, 1);
    }

    #[test]
    fn bare_soak_run_gets_the_default_staging() {
        let c = ExperimentConfig::from_str("run = \"soak\"\n").unwrap();
        assert_eq!(c.run, RunConfig::Soak);
        assert_eq!(c.soak, Some(SoakRun::default()));
        let s = c.soak.unwrap();
        assert_eq!(s.shape.total_jobs(), 60);
        assert_eq!(s.shape.stages().len(), 3); // ramp in, burst, steady soak
        // top-level jobs/gap_secs parameterize the same default staging
        let c = ExperimentConfig::from_str(
            "run = \"soak\"\n[load]\njobs = 40\ngap_secs = 15\n",
        )
        .unwrap();
        let s = c.soak.unwrap();
        assert_eq!(s.shape.total_jobs(), 40);
        assert_eq!(s.shape.stages(), &SoakRun::staged(40, 15.0)[..]);
        // tiny counts collapse to a single soak stage
        assert_eq!(SoakRun::staged(4, 30.0), vec![LoadStage::soak(4, 30.0)]);
    }

    #[test]
    fn load_rejects_unknown_keys_and_undeclared_stages() {
        // a typo must not silently soak a different load
        let r = ExperimentConfig::from_str("run = \"soak\"\n[load]\njob = 4\n");
        assert!(r.unwrap_err().to_string().contains("job"));
        let r = ExperimentConfig::from_str(
            "run = \"soak\"\n[load]\nstages = \"a\"\n[load.b]\njobs = 4\n",
        );
        assert!(r.unwrap_err().to_string().contains("not declared"));
        let r = ExperimentConfig::from_str(
            "run = \"soak\"\n[load]\nstages = \"a\"\n\
             [load.a]\njobs = 4\ngap_secs = 30\nfactr = 2\n",
        );
        assert!(r.unwrap_err().to_string().contains("factr"));
    }

    #[test]
    fn load_rejects_mistyped_and_unsafe_values() {
        for bad in [
            // shorthand knobs: mistyped / non-positive
            "run = \"soak\"\n[load]\njobs = 0\n",
            "run = \"soak\"\n[load]\njobs = 2.5\n",
            "run = \"soak\"\n[load]\ngap_secs = 0\n",
            "run = \"soak\"\n[load]\ngap_secs = \"30\"\n",
            // size models: exclusive, complete, well-shaped
            "run = \"soak\"\n[load]\nsizes_mb = 150\n",
            "run = \"soak\"\n[load]\nsizes_mb = []\n",
            "run = \"soak\"\n[load]\nsizes_mb = [150, 0]\n",
            "run = \"soak\"\n[load]\nsizes_mb = [150]\npareto_alpha = 1.5\n\
             pareto_min_mb = 100\npareto_cap_mb = 600\n",
            "run = \"soak\"\n[load]\npareto_alpha = 1.5\n",
            "run = \"soak\"\n[load]\npareto_alpha = 1.5\npareto_min_mb = 600\n\
             pareto_cap_mb = 100\n",
            // diurnal: both knobs or neither, amplitude below 1
            "run = \"soak\"\n[load]\ndiurnal_amplitude = 0.3\n",
            "run = \"soak\"\n[load]\ndiurnal_amplitude = 1.5\n\
             diurnal_period_secs = 86400\n",
            // driver knobs
            "run = \"soak\"\n[load]\ntarget_p95_slowdown = 0.5\n",
            "run = \"soak\"\n[load]\nsketch_cap = 0\n",
            "run = \"soak\"\n[load]\ngc_period_secs = 0\n",
            "run = \"soak\"\n[load]\nmax_active = 0\n",
            "run = \"soak\"\n[load]\nthreads = 0\n",
            // stage declarations
            "run = \"soak\"\n[load]\nstages = \"a, a\"\n[load.a]\njobs = 4\ngap_secs = 30\n",
            "run = \"soak\"\n[load]\nstages = \"a.b\"\n",
            "run = \"soak\"\n[load]\nstages = \"jobs\"\n",
            "run = \"soak\"\n[load]\nstages = \"a\"\njobs = 5\n\
             [load.a]\njobs = 4\ngap_secs = 30\n",
            // per-stage contracts: required and inapplicable knobs
            "run = \"soak\"\n[load]\nstages = \"a\"\n[load.a]\ngap_secs = 30\n",
            "run = \"soak\"\n[load]\nstages = \"a\"\n[load.a]\njobs = 4\n",
            "run = \"soak\"\n[load]\nstages = \"a\"\n\
             [load.a]\njobs = 4\ngap_secs = 30\nfactor = 2\n",
            "run = \"soak\"\n[load]\nstages = \"a\"\n\
             [load.a]\nshape = \"ramp\"\njobs = 4\ngap_secs = 30\n",
            "run = \"soak\"\n[load]\nstages = \"a\"\n\
             [load.a]\nshape = \"spike\"\njobs = 4\ngap_secs = 30\n",
            "run = \"soak\"\n[load]\nstages = \"a\"\n\
             [load.a]\nshape = \"concentrated\"\njobs = 4\ngap_secs = 30\n\
             within_secs = 60\n",
            "run = \"soak\"\n[load]\nstages = \"a\"\n\
             [load.a]\nshape = \"burst\"\njobs = 4\ngap_secs = 30\n",
            // cross-run: [load] only means something to the soak run
            "run = \"table1\"\n[load]\njobs = 4\n",
        ] {
            assert!(ExperimentConfig::from_str(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn cell_seed_shared_between_table1_and_scenarios() {
        // one formula, two consumers: Table I cells and scenario grids
        let cfg = Table1Config::paper(JobKind::Sort);
        let spec = cfg.cell_spec(600.0, SchedulerKind::Bass);
        assert_eq!(spec.seed, cell_seed(cfg.seed, 600.0));
    }
}
