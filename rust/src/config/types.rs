//! Typed experiment configuration assembled from the parsed table.

use crate::experiments::{SchedulerKind, Table1Config};
use crate::workload::JobKind;

use super::parser::{parse, Table};

/// What to run (CLI subcommand equivalents).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RunConfig {
    Example1,
    Example3 { background: usize },
    Table1 { kind: JobKind },
    Fig5,
    E2e { jobs: usize },
}

/// Full experiment file: run selector + sweep overrides.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub run: RunConfig,
    pub table1: Table1Config,
}

impl ExperimentConfig {
    /// Defaults: Example 1 + the paper's Table I(a) configuration.
    pub fn default_wordcount() -> Self {
        Self { run: RunConfig::Example1, table1: Table1Config::paper(JobKind::Wordcount) }
    }

    /// Load from a TOML-subset file (see `examples/experiment.toml`).
    pub fn from_str(text: &str) -> anyhow::Result<Self> {
        let t = parse(text)?;
        let kind = match t.get(".job").and_then(|v| v.as_str()).unwrap_or("wordcount") {
            "sort" => JobKind::Sort,
            _ => JobKind::Wordcount,
        };
        let mut cfg = Table1Config::paper(kind);
        apply_table1(&mut cfg, &t);
        let run = match t.get(".run").and_then(|v| v.as_str()).unwrap_or("example1") {
            "example3" => RunConfig::Example3 {
                background: t
                    .get("example3.background")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(5),
            },
            "table1" => RunConfig::Table1 { kind },
            "fig5" => RunConfig::Fig5,
            "e2e" => RunConfig::E2e {
                jobs: t.get("e2e.jobs").and_then(|v| v.as_usize()).unwrap_or(10),
            },
            _ => RunConfig::Example1,
        };
        Ok(Self { run, table1: cfg })
    }
}

fn apply_table1(cfg: &mut Table1Config, t: &Table) {
    if let Some(v) = t.get("cluster.link_mbps").and_then(|v| v.as_f64()) {
        cfg.link_mbps = v;
    }
    if let Some(v) = t.get("cluster.switches").and_then(|v| v.as_usize()) {
        cfg.n_switches = v;
    }
    if let Some(v) = t.get("cluster.hosts_per_switch").and_then(|v| v.as_usize()) {
        cfg.hosts_per_switch = v;
    }
    if let Some(v) = t.get("cluster.replication").and_then(|v| v.as_usize()) {
        cfg.replication = v;
    }
    if let Some(v) = t.get("sweep.sizes_mb").and_then(|v| v.as_nums()) {
        cfg.sizes_mb = v.to_vec();
    }
    if let Some(v) = t.get("sweep.seed").and_then(|v| v.as_usize()) {
        cfg.seed = v as u64;
    }
    if let Some(v) = t.get("sweep.schedulers").and_then(|v| v.as_str()) {
        let parsed: Vec<SchedulerKind> =
            v.split(',').filter_map(|s| SchedulerKind::parse(s.trim())).collect();
        if !parsed.is_empty() {
            cfg.schedulers = parsed;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_example1() {
        let c = ExperimentConfig::default_wordcount();
        assert_eq!(c.run, RunConfig::Example1);
    }

    #[test]
    fn file_overrides_apply() {
        let c = ExperimentConfig::from_str(
            r#"
run = "table1"
job = "sort"

[cluster]
link_mbps = 200
switches = 3
hosts_per_switch = 2

[sweep]
sizes_mb = [150, 300]
seed = 99
"#,
        )
        .unwrap();
        assert_eq!(c.run, RunConfig::Table1 { kind: JobKind::Sort });
        assert_eq!(c.table1.link_mbps, 200.0);
        assert_eq!(c.table1.n_switches, 3);
        assert_eq!(c.table1.hosts_per_switch, 2);
        assert_eq!(c.table1.sizes_mb, vec![150.0, 300.0]);
        assert_eq!(c.table1.seed, 99);
    }

    #[test]
    fn scheduler_list_parses() {
        let c = ExperimentConfig::from_str("[sweep]\nschedulers = \"bass, hds\"\n").unwrap();
        assert_eq!(c.table1.schedulers.len(), 2);
    }
}
