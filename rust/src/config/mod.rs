//! Typed configuration + a minimal TOML-subset parser.
//!
//! The offline image vendors no serde/toml, so [`parser`] implements the
//! subset the configs need: `[section]` headers, `key = value` with
//! string / number / bool / arrays of numbers, and `#` comments.

pub mod parser;
pub mod types;

pub use parser::{parse, Value};
pub use types::{
    ExperimentConfig, FairnessRun, RunConfig, ScaleRun, ScenarioSweep, SoakRun, StreamRun,
};
