//! Minimal TOML-subset parser: sections, scalars, number arrays.

use std::collections::BTreeMap;

/// A parsed value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
    NumArray(Vec<f64>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|x| *x >= 0.0 && x.fract() == 0.0).map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_nums(&self) -> Option<&[f64]> {
        match self {
            Value::NumArray(v) => Some(v),
            _ => None,
        }
    }
}

/// `section.key -> value` (top-level keys use an empty section: `.key`).
pub type Table = BTreeMap<String, Value>;

/// Parse the TOML subset; errors carry the offending line number.
pub fn parse(text: &str) -> anyhow::Result<Table> {
    let mut out = Table::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            section = name.trim().to_string();
            anyhow::ensure!(!section.is_empty(), "line {}: empty section", lineno + 1);
            // marker entry (`"section."` -> true): lets consumers detect a
            // section header even when every key under it is omitted
            out.insert(format!("{section}."), Value::Bool(true));
            continue;
        }
        let Some((k, v)) = line.split_once('=') else {
            anyhow::bail!("line {}: expected `key = value`, got {line:?}", lineno + 1);
        };
        let key = format!("{section}.{}", k.trim());
        let value = parse_value(v.trim())
            .ok_or_else(|| anyhow::anyhow!("line {}: bad value {v:?}", lineno + 1))?;
        out.insert(key, value);
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // naive but sufficient: no '#' inside strings in our configs
    match line.find('#') {
        Some(i) => &line[..i],
        None => line,
    }
}

fn parse_value(s: &str) -> Option<Value> {
    if s == "true" {
        return Some(Value::Bool(true));
    }
    if s == "false" {
        return Some(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('"').and_then(|x| x.strip_suffix('"')) {
        return Some(Value::Str(inner.to_string()));
    }
    if let Some(inner) = s.strip_prefix('[').and_then(|x| x.strip_suffix(']')) {
        let items: Result<Vec<f64>, _> =
            inner.split(',').filter(|x| !x.trim().is_empty()).map(|x| x.trim().parse()).collect();
        return items.ok().map(Value::NumArray);
    }
    s.parse::<f64>().ok().map(Value::Num)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_all_value_kinds() {
        let t = parse(
            r#"
# top comment
name = "sweep"
threads = 4
ratio = 0.5
verbose = true

[cluster]
link_mbps = 100
sizes = [150, 300, 600]  # trailing comment
"#,
        )
        .unwrap();
        assert_eq!(t[".name"].as_str(), Some("sweep"));
        assert_eq!(t[".threads"].as_usize(), Some(4));
        assert_eq!(t[".ratio"].as_f64(), Some(0.5));
        assert_eq!(t[".verbose"].as_bool(), Some(true));
        assert_eq!(t["cluster.link_mbps"].as_f64(), Some(100.0));
        assert_eq!(t["cluster.sizes"].as_nums(), Some(&[150.0, 300.0, 600.0][..]));
        // section headers leave a marker even with all keys omitted
        assert_eq!(t["cluster."].as_bool(), Some(true));
        assert!(parse("[empty]\n").unwrap().contains_key("empty."));
    }

    #[test]
    fn rejects_garbage_with_line_numbers() {
        let err = parse("a = 1\nnot a kv\n").unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn rejects_bad_value() {
        let err = parse("a = {oops}\n").unwrap_err().to_string();
        assert!(err.contains("bad value"), "{err}");
    }

    #[test]
    fn type_coercions_are_strict() {
        let t = parse("x = 1.5\n").unwrap();
        assert_eq!(t[".x"].as_usize(), None); // not integral
        assert_eq!(t[".x"].as_str(), None);
    }
}
