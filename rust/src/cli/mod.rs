//! Hand-rolled CLI (clap is not vendored offline). Subcommands map 1:1 to
//! the experiment drivers; `bass --help` documents them.

use crate::config::{ExperimentConfig, FairnessRun, RunConfig, ScenarioSweep, SoakRun, StreamRun};
use crate::coordinator::{ClusterSetup, Coordinator};
use crate::experiments::{
    ablate_background, ablate_heterogeneity, ablate_slot_duration, run_dynamics,
    run_estimate, run_example1, run_example3, run_fairness_sweep, run_fairness_sweep_with,
    run_fig5, run_scale, run_scale_fat_with, run_skew, run_soak_sweep_with,
    run_stream_sweep_with, run_table1, FairnessPoint, SchedulerKind, SoakPoint, StreamPoint,
    Table1Config,
};
use crate::metrics::NodeTimeline;
use crate::runtime::CostModel;
use crate::scenario::{run_dynamic_grid, run_job_grid, MitigationSpec, SpeculationMode};
use crate::trace;
use crate::util::XorShift;
use crate::workload::{JobKind, LoadShape, SizeDist, TraceGen};

pub const HELP: &str = "\
bass — Bandwidth-Aware Scheduling with SDN in Hadoop (reproduction)

USAGE: bass <COMMAND> [OPTIONS]

COMMANDS:
  example1              Example 1/2 + Fig 3/4: the 4-node walk-through
  example3 [--bg N]     Example 3: QoS queues vs shared queue
  table1 --job J        Table I sweep (J = wordcount | sort)
  fig5                  Fig 5: JT curves for both jobs
  e2e [--jobs N]        End-to-end online trace through the coordinator
  ablate                Slot-duration / background / heterogeneity ablations
  scale [--fat]         Cluster-size scalability sweep (paper future work);
        [--hosts h1,h2] --fat runs the 8-leaf fat-tree grid (default up to
        [--shards N]    1024 hosts); --hosts picks total host counts
                        (positive multiples of 8) and --shards caps the
                        scheduler-state shard count — sharding is
                        schedule-invariant, only wall times move
  dynamics [--levels l] Churn sweep: BASS/BAR/HDS under node failures, link
        [--mitigation M]  degradation, stragglers and cross traffic (levels
                        0 = static .. heavy; default 0,0.5,1,2); M = off |
                        late | bw_aware turns on straggler mitigation —
                        speculative duplicates of slow outliers, bw_aware
                        gates each duplicate on a serviceable network path
  estimate [--noises n] Estimate-error sweep: BASS/BAR/HDS scheduled from
        [--periods p]   probed EWMA bandwidth estimates instead of the
                        clairvoyant oracle, with mid-flow reallocation of
                        drifting grants at probe epochs (noises = relative
                        probe sigma, default 0,0.1,0.3; periods = probe
                        gaps in seconds, 0 = continuous, default 1,5,20)
  stream [--rates g]    Online multi-job stream sweep: BASS/BAR/HDS under a
         [--jobs N]     Poisson arrival stream at each mean gap g seconds
                        (default 120,30,10); overlapping jobs share slots,
                        the SDN calendar and the flow network
  fairness [--weights w] Multi-tenant stream sweep: the arrival stream is
         [--rates g]    split round-robin between a guaranteed \"prod\"
         [--jobs N]     tenant (DRF weight w, default 1,2,4) and a spot
                        \"batch\" tenant (weight 1); admission is dominant-
                        resource fair over (slots, reserved bandwidth)
                        instead of FIFO; reports per-tenant slowdowns,
                        SLO attainment, Jain index, rejections and
                        preemptions
  soak [--jobs N]       Sustained-load soak sweep: BASS/BAR/HDS under one
       [--gap g]        shaped arrival trace (ramp in, burst at 4x, steady
       [--seed N]       soak at mean gap g seconds) played through the
       [--target x]     bounded-memory soak driver; per-job state folds
                        into streaming sketches at completion, and the
                        figure of merit is jobs/hour sustained while the
                        p95 slowdown stays at or under the target
                        (default 2.0)
  skew [--reps r1,r2]   Replication/skew sweep: HDS/BAR/BASS (and BASS under
                        the legacy idle-only source rule) across placement
                        policies (random, rack_aware, hotspot) at each
                        dfs.replication factor (default 1,2,3)
  scenario --config F   Run a user-defined scenario sweep from a TOML file
  run --config F        Run the experiment described by a TOML file
  help                  Show this message

OPTIONS:
  --sizes a,b,c         Override sweep sizes (MB)
  --sched s1,s2         Override scheduler list (hds,bar,bass,pre-bass)
  --seed N              Override workload seed
  --threads N           Fan sweep points across N worker threads
                        (results are bitwise-identical to --threads 1)

DEFINE YOUR OWN SCENARIO:
  `bass scenario --config my.toml` runs any cluster/workload grid without
  a new driver. A scenario file sets `run = \"scenario\"` plus:
    job = \"wordcount\" | \"sort\"       threads = N
    [cluster]  topology = \"tree\"|\"fig2\", switches, hosts_per_switch,
               link_mbps, uplink_mbps, replication,
               placement = \"random\"|\"round_robin\"
    [hdfs]     replication, placement = \"random\"|\"round_robin\"|
               \"rack_aware\"|\"hotspot\" (hotspot_nodes, hotspot_bias),
               selection = \"bandwidth\"|\"min_idle\" (replica source rule)
    [sdn]      slot_secs, qos = \"example3\"|\"shared\"
    [background] flows, rate_mb_s, max_initial_idle
    [sweep]    sizes_mb = [..], schedulers = \"bass, bar, hds\",
               seed, reduces, slowstart
    [dynamics] node_failures, mttr_secs, link_degradations, degrade_floor,
               degrade_secs, stragglers, straggle_factor, straggle_secs,
               cross_flows, cross_rate_mb_s, cross_secs, horizon_secs, seed
    [mitigation] speculation = \"off\"|\"late\"|\"bw_aware\", slow_threshold,
               evict_factor, rebalance_period (straggler reaction layered
               on the [dynamics] churn route)
    [telemetry] probe_period (seconds, 0 = continuous), noise (relative
               sigma), alpha (EWMA gain), stale_secs, seed,
               reallocate = true|false — schedule from probed EWMA
               bandwidth estimates instead of the clairvoyant oracle;
               no [telemetry] table = bit-identical clairvoyant runs
    [tenants]  names = \"prod, batch\" declares the tenants, then one
               [tenants.<name>] table each with weight, slot_quota,
               bw_quota, class = \"guaranteed\"|\"spot\", deadline_secs;
               carried on the spec for stream drivers — no [tenants]
               table = the FIFO stream path, bit-identical to before
  Every (size, scheduler) cell is a hermetic SimSession: same seed =>
  same block layout and background, so all deltas are scheduling. With a
  [dynamics] table the sweep runs each cell's map wave through the churn
  pipeline (seeded node failures / link degradation / stragglers / cross
  traffic) instead of the static two-phase job.

DEFINE YOUR OWN STREAM:
  `bass run --config my.toml` with `run = \"stream\"` plays an online
  multi-job sweep; the optional [stream] table sets
    jobs, rates = [mean gaps, sparse..heavy], sizes_mb,
    max_active (admission cap), min_free_slots (slot gate), seed
  Every scheduler at one rate faces the identical Poisson arrival trace;
  per-job slowdown is measured against the same job run alone.

DEFINE YOUR OWN SOAK:
  `bass run --config my.toml` with `run = \"soak\"` plays a shaped trace
  through the bounded-memory soak driver; the optional [load] table sets
    jobs, gap_secs (shorthand: the default ramp/burst/soak staging), or
    stages = \"warmup, burst, steady\" plus one [load.<stage>] table each
    with shape = \"soak\"|\"ramp\"|\"spike\"|\"concentrated\", jobs, gap_secs
    (to_gap_secs for ramp, factor for spike, within_secs for
    concentrated); sizes_mb = [..] or pareto_alpha/pareto_min_mb/
    pareto_cap_mb (heavy-tailed sizes); diurnal_amplitude +
    diurnal_period_secs; seed, max_active, min_free_slots,
    target_p95_slowdown, sketch_cap, gc_period_secs, threads
  Every scheduler faces the identical shaped trace; the report is O(1)
  in stream length (sketches + counters, no per-job outcome list).

DEFINE YOUR OWN FAIRNESS SWEEP:
  `bass run --config my.toml` with `run = \"fairness\"` plays the
  multi-tenant stream sweep; the optional [fairness] table sets
    weights = [prod DRF weights], rates = [mean gaps], jobs, threads
  and an optional [tenants] table (see above) replaces the built-in
  prod/batch pair entirely (then weights must be omitted).

DEFINE YOUR OWN SCALE SWEEP:
  `bass run --config my.toml` with `run = \"scale\"` plays the
  scalability sweep; the optional [scale] table sets
    fat = true|false, hosts = [multiples of 8], shards = N, threads = N
  (hosts/shards require fat = true). Sharding only regroups candidate
  scans: every metric is bit-identical under any shard cap.
";

/// Parse `--key value` style options from the arg list.
fn opt(args: &[String], key: &str) -> Option<String> {
    args.iter().position(|a| a == key).and_then(|i| args.get(i + 1).cloned())
}

fn opt_threads(args: &[String]) -> usize {
    opt(args, "--threads").and_then(|s| s.parse().ok()).map(|t: usize| t.max(1)).unwrap_or(1)
}

/// Entry point used by main.rs; returns process exit code.
pub fn run(args: Vec<String>) -> i32 {
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let cost = CostModel::auto();
    match cmd {
        "example1" => {
            println!("== Example 1/2 (Fig 3 + Fig 4) ==");
            for o in run_example1(&cost) {
                println!(
                    "\n{}: estimated JT {:.0}s, executed JT {:.0}s (paper: {})",
                    o.scheduler,
                    o.estimated_jt,
                    o.executed_jt,
                    match o.scheduler {
                        "HDS" => "39s",
                        "BAR" => "38s",
                        "BASS" => "35s",
                        _ => "34s",
                    }
                );
                print!("{}", NodeTimeline::render(&o.timelines, 1.0));
            }
            0
        }
        "example3" => {
            let bg = opt(&args, "--bg").and_then(|s| s.parse().ok()).unwrap_or(5);
            let o = run_example3(bg);
            println!("== Example 3 (QoS queues, {bg} background flows) ==");
            println!("shared 150Mbps queue : shuffle done in {:.1}s", o.shared_secs);
            println!("Q1/Q2/Q3 queues      : shuffle done in {:.1}s", o.queued_secs);
            println!("speedup              : {:.2}x", o.speedup);
            0
        }
        "table1" => {
            let kind = match opt(&args, "--job").as_deref() {
                Some("sort") => JobKind::Sort,
                _ => JobKind::Wordcount,
            };
            let mut cfg = Table1Config::paper(kind);
            apply_overrides(&mut cfg, &args);
            println!("== Table I ({}) ==", kind.label());
            let rows = run_table1(&cfg, &cost);
            print!("{}", trace::table1_markdown(&rows));
            0
        }
        "fig5" => {
            let sizes = opt(&args, "--sizes").map(parse_sizes);
            for p in run_fig5(&cost, sizes, opt_threads(&args)) {
                println!("== Fig 5: {} ==", p.job);
                print!("size(MB):");
                for s in &p.sizes_mb {
                    print!("\t{s:.0}");
                }
                println!();
                for (name, jts) in &p.series {
                    print!("{name}:");
                    for j in jts {
                        print!("\t{j:.0}");
                    }
                    println!();
                }
            }
            0
        }
        "e2e" => {
            // clamp to >= 1: `--jobs 0` must not divide the mean by zero
            let n = opt(&args, "--jobs").and_then(|s| s.parse().ok()).unwrap_or(10).max(1);
            println!("== E2E online trace ({n} jobs) ==");
            for kind in [SchedulerKind::Bass, SchedulerKind::Hds] {
                let mut rng = XorShift::new(2014);
                let arrivals = TraceGen::default().generate(n, &mut rng);
                let coord = Coordinator::new(ClusterSetup::default(), kind, CostModel::auto());
                let results = match coord.run_trace(arrivals) {
                    Ok(r) => r,
                    Err(e) => {
                        eprintln!("e2e trace failed: {e}");
                        return 1;
                    }
                };
                let total: f64 = results.iter().map(|r| r.metrics.jt).sum();
                println!(
                    "\n[{}] {} jobs, mean JT {:.1}s",
                    kind.label(),
                    results.len(),
                    total / n as f64
                );
                for r in &results {
                    println!("  t={:>7.1}s {:<18} {}", r.submitted_at, r.name, r.metrics);
                }
            }
            0
        }
        "ablate" => {
            let cost = CostModel::rust_only();
            println!("== ablations ==");
            for p in ablate_slot_duration(&[0.25, 1.0, 2.0, 4.0], &cost) {
                println!("slot ts={:<5} {:<5} JT {:.1}s", p.x, p.scheduler, p.jt);
            }
            for p in ablate_background(&[0, 2, 4, 8], &cost) {
                println!("bg n={:<5} {:<5} JT {:.1}s", p.x, p.scheduler, p.jt);
            }
            for (s, jt) in ablate_heterogeneity(3.0, &cost) {
                println!("hetero 3x-slow-half {:<5} JT {:.1}s", s, jt);
            }
            0
        }
        "scale" => {
            let threads = opt_threads(&args);
            let fat = args.iter().any(|a| a == "--fat");
            let shards = match opt(&args, "--shards") {
                None => None,
                Some(raw) => match raw.trim().parse::<usize>() {
                    Ok(n) if n >= 1 => Some(n),
                    _ => {
                        eprintln!("--shards must be a positive shard count, got {raw:?}");
                        return 2;
                    }
                },
            };
            let hosts: Option<Vec<usize>> = match opt(&args, "--hosts") {
                None => None,
                Some(raw) => {
                    // same contract as --reps/--rates: a typo'd entry
                    // must error, not silently run a different sweep
                    let wanted = raw.split(',').filter(|s| !s.trim().is_empty()).count();
                    let v: Vec<usize> = raw
                        .split(',')
                        .filter_map(|x| x.trim().parse().ok())
                        .filter(|&h| h >= 8 && h % 8 == 0)
                        .collect();
                    if v.is_empty() || v.len() != wanted {
                        eprintln!(
                            "--hosts must be a comma list of positive multiples of 8 \
                             (the grids use 8 leaves/switches), got {raw:?}"
                        );
                        return 2;
                    }
                    Some(v)
                }
            };
            if !fat && (shards.is_some() || hosts.is_some()) {
                eprintln!("--shards/--hosts apply to the fat-tree grid: add --fat");
                return 2;
            }
            run_scale_cmd(fat, hosts, shards, threads)
        }
        "dynamics" => {
            let levels = opt(&args, "--levels")
                .map(parse_sizes)
                .filter(|v| !v.is_empty())
                .unwrap_or_else(|| vec![0.0, 0.5, 1.0, 2.0]);
            // same contract as --reps/--rates: a typo'd mode must error,
            // not silently run the unmitigated sweep
            let mitigation = match opt(&args, "--mitigation") {
                None => MitigationSpec::off(),
                Some(raw) => match SpeculationMode::parse(raw.trim()) {
                    Some(SpeculationMode::Off) => MitigationSpec::off(),
                    Some(SpeculationMode::Late) => MitigationSpec::late(),
                    Some(SpeculationMode::BwAware) => MitigationSpec::bw_aware(),
                    None => {
                        eprintln!("--mitigation must be off, late or bw_aware, got {raw:?}");
                        return 2;
                    }
                },
            };
            let threads = opt_threads(&args);
            println!(
                "== dynamics churn sweep ({} levels, mitigation {}, {threads} threads) ==",
                levels.len(),
                mitigation.speculation.label()
            );
            println!(
                "{:<7} {:<5} {:<8} {:>10} {:>8} {:>9} {:>7} {:>5} {:>5} {:>7} {:>8} {:>10}",
                "churn", "sched", "mit", "makespan", "LR", "reassign", "rounds", "spec",
                "wins", "defer", "underrep", "completed"
            );
            for p in run_dynamics(&levels, &CostModel::rust_only(), threads, &mitigation) {
                println!(
                    "{:<7.2} {:<5} {:<8} {:>9.1}s {:>7.1}% {:>9} {:>7} {:>5} {:>5} {:>7} \
                     {:>8} {:>7}/{}",
                    p.churn,
                    p.scheduler,
                    p.mitigation,
                    p.makespan,
                    p.locality * 100.0,
                    p.reassignments,
                    p.rounds,
                    p.speculated,
                    p.spec_wins,
                    p.deferrals,
                    p.under_replicated_peak,
                    p.completed,
                    p.tasks
                );
            }
            0
        }
        "estimate" => {
            // same contract as --reps/--rates: a typo'd entry must
            // error, not silently run a different sweep
            let axis = |key: &str, default: Vec<f64>| -> Result<Vec<f64>, String> {
                match opt(&args, key) {
                    None => Ok(default),
                    Some(raw) => {
                        let wanted = raw.split(',').filter(|s| !s.trim().is_empty()).count();
                        let v = parse_sizes(raw.clone());
                        if v.is_empty() || v.len() != wanted || v.iter().any(|&x| x < 0.0) {
                            return Err(raw);
                        }
                        Ok(v)
                    }
                }
            };
            let noises = match axis("--noises", vec![0.0, 0.1, 0.3]) {
                Ok(v) => v,
                Err(raw) => {
                    eprintln!(
                        "--noises must be a comma list of non-negative sigmas, got {raw:?}"
                    );
                    return 2;
                }
            };
            let periods = match axis("--periods", vec![1.0, 5.0, 20.0]) {
                Ok(v) => v,
                Err(raw) => {
                    eprintln!(
                        "--periods must be a comma list of non-negative probe gaps \
                         (seconds, 0 = continuous), got {raw:?}"
                    );
                    return 2;
                }
            };
            let threads = opt_threads(&args);
            println!(
                "== estimate-error sweep ({} noises x {} periods x 3 schedulers, \
                 {threads} threads) ==",
                noises.len(),
                periods.len()
            );
            println!(
                "{:<7} {:<9} {:<5} {:>10} {:>8} {:>7} {:>8} {:>10}",
                "noise", "period(s)", "sched", "makespan", "LR", "probes", "realloc",
                "completed"
            );
            for p in run_estimate(&noises, &periods, &CostModel::rust_only(), threads) {
                println!(
                    "{:<7.2} {:<9.1} {:<5} {:>9.1}s {:>7.1}% {:>7} {:>8} {:>7}/{}",
                    p.noise,
                    p.probe_period,
                    p.scheduler,
                    p.makespan,
                    p.locality * 100.0,
                    p.probes,
                    p.reallocations,
                    p.completed,
                    p.tasks
                );
            }
            0
        }
        "skew" => {
            let reps: Vec<usize> = match opt(&args, "--reps") {
                None => vec![1, 2, 3],
                Some(raw) => {
                    // same contract as the [hdfs] table: a typo'd factor
                    // must error, not silently run a different sweep
                    let wanted = raw.split(',').filter(|s| !s.trim().is_empty()).count();
                    let v: Vec<usize> = raw
                        .split(',')
                        .filter_map(|x| x.trim().parse().ok())
                        .filter(|&r| r >= 1 && r <= crate::experiments::skew::SKEW_NODES)
                        .collect();
                    if v.is_empty() || v.len() != wanted {
                        eprintln!(
                            "--reps must be a comma list of replication factors in [1, {}] \
                             (the sweep's cluster size), got {raw:?}",
                            crate::experiments::skew::SKEW_NODES
                        );
                        return 2;
                    }
                    v
                }
            };
            let threads = opt_threads(&args);
            println!(
                "== replication/skew sweep ({} factors x 3 placements, {threads} threads) ==",
                reps.len()
            );
            println!(
                "{:<4} {:<12} {:<10} {:>10} {:>8} {:>8}",
                "rep", "placement", "sched", "makespan", "LR", "remote"
            );
            for p in run_skew(&reps, &CostModel::rust_only(), threads) {
                println!(
                    "{:<4} {:<12} {:<10} {:>9.1}s {:>7.1}% {:>8}",
                    p.replication,
                    p.placement,
                    p.scheduler,
                    p.makespan,
                    p.locality * 100.0,
                    p.remote_pulls
                );
            }
            0
        }
        "stream" => {
            let mut run = StreamRun::default();
            if let Some(raw) = opt(&args, "--rates") {
                // same contract as the [stream] table: a typo'd knob
                // must error, not silently run a different sweep
                let wanted = raw.split(',').filter(|s| !s.trim().is_empty()).count();
                let v = parse_sizes(raw.clone());
                if v.is_empty() || v.len() != wanted || v.iter().any(|&g| g <= 0.0) {
                    eprintln!(
                        "--rates must be a comma list of positive mean gaps (seconds), \
                         got {raw:?}"
                    );
                    return 2;
                }
                run.rates = v;
            }
            if let Some(j) = opt(&args, "--jobs").and_then(|s| s.parse().ok()) {
                run.spec.jobs = std::cmp::max(j, 1);
            }
            let threads = opt_threads(&args);
            println!(
                "== online stream sweep ({} rates x 3 schedulers, {} jobs, {threads} threads) ==",
                run.rates.len(),
                run.spec.jobs
            );
            print_stream_points(&run_stream_sweep_with(
                &run.spec,
                &run.rates,
                &CostModel::rust_only(),
                threads,
            ));
            0
        }
        "fairness" => {
            let mut run = FairnessRun::default();
            // same contract as --reps/--rates: a typo'd axis must error,
            // not silently run a different sweep
            let axis = |key: &str| -> Result<Option<Vec<f64>>, String> {
                match opt(&args, key) {
                    None => Ok(None),
                    Some(raw) => {
                        let wanted = raw.split(',').filter(|s| !s.trim().is_empty()).count();
                        let v = parse_sizes(raw.clone());
                        if v.is_empty() || v.len() != wanted || v.iter().any(|&x| x <= 0.0) {
                            return Err(raw);
                        }
                        Ok(Some(v))
                    }
                }
            };
            match axis("--weights") {
                Ok(Some(v)) => run.weights = v,
                Ok(None) => {}
                Err(raw) => {
                    eprintln!(
                        "--weights must be a comma list of positive DRF weights, got {raw:?}"
                    );
                    return 2;
                }
            }
            match axis("--rates") {
                Ok(Some(v)) => run.rates = v,
                Ok(None) => {}
                Err(raw) => {
                    eprintln!(
                        "--rates must be a comma list of positive mean gaps (seconds), \
                         got {raw:?}"
                    );
                    return 2;
                }
            }
            if let Some(raw) = opt(&args, "--jobs") {
                match raw.trim().parse::<usize>() {
                    Ok(n) if n >= 1 => run.jobs = n,
                    _ => {
                        eprintln!("--jobs must be a positive job count, got {raw:?}");
                        return 2;
                    }
                }
            }
            let threads = opt_threads(&args);
            println!(
                "== multi-tenant fairness sweep ({} weights x {} rates x 3 schedulers, \
                 {} jobs, {threads} threads) ==",
                run.weights.len(),
                run.rates.len(),
                run.jobs
            );
            print_fairness_points(&run_fairness_sweep(
                &run.weights,
                &run.rates,
                run.jobs,
                &CostModel::rust_only(),
                threads,
            ));
            0
        }
        "soak" => {
            let mut run = SoakRun::default();
            let mut jobs = run.shape.total_jobs();
            let mut gap = 30.0;
            // same contract as --reps/--rates: a typo'd knob must error,
            // not silently soak a different load
            if let Some(raw) = opt(&args, "--jobs") {
                match raw.trim().parse::<usize>() {
                    Ok(n) if n >= 1 => jobs = n,
                    _ => {
                        eprintln!("--jobs must be a positive job count, got {raw:?}");
                        return 2;
                    }
                }
            }
            if let Some(raw) = opt(&args, "--gap") {
                match raw.trim().parse::<f64>() {
                    Ok(g) if g > 0.0 && g.is_finite() => gap = g,
                    _ => {
                        eprintln!("--gap must be a positive mean gap (seconds), got {raw:?}");
                        return 2;
                    }
                }
            }
            run.shape = LoadShape::new(
                SoakRun::staged(jobs, gap),
                SizeDist::Menu(vec![150.0, 300.0, 600.0]),
                None,
            )
            .expect("staged default shape is valid");
            if let Some(raw) = opt(&args, "--seed") {
                match raw.trim().parse::<u64>() {
                    Ok(s) => run.seed = s,
                    _ => {
                        eprintln!("--seed must be a non-negative integer, got {raw:?}");
                        return 2;
                    }
                }
            }
            if let Some(raw) = opt(&args, "--target") {
                match raw.trim().parse::<f64>() {
                    Ok(x) if x >= 1.0 && x.is_finite() => run.target_p95_slowdown = x,
                    _ => {
                        eprintln!(
                            "--target is a p95-slowdown SLO: must be >= 1, got {raw:?}"
                        );
                        return 2;
                    }
                }
            }
            let threads = opt_threads(&args);
            println!(
                "== sustained-load soak sweep ({jobs} jobs, {} stages, target p95 \
                 slowdown {:.1}x, {threads} threads) ==",
                run.shape.stages().len(),
                run.target_p95_slowdown
            );
            print_soak_points(&run_soak_sweep_with(
                &run.shape,
                run.seed,
                run.policy(),
                run.soak_config(),
                &CostModel::rust_only(),
                threads,
            ));
            0
        }
        "scenario" => {
            let Some(path) = opt(&args, "--config") else {
                eprintln!("scenario requires --config <file>\n\n{HELP}");
                return 2;
            };
            let cfg = match load_config(&path) {
                Ok(c) => c,
                Err(code) => return code,
            };
            let Some(sweep) = cfg.scenario else {
                eprintln!("{path} is not a scenario file (needs run = \"scenario\")");
                return 2;
            };
            run_scenario(&sweep, &path, &args, &cost)
        }
        "run" => {
            let Some(path) = opt(&args, "--config") else {
                eprintln!("run requires --config <file>\n\n{HELP}");
                return 2;
            };
            let cfg = match load_config(&path) {
                Ok(c) => c,
                Err(code) => return code,
            };
            match cfg.run {
                RunConfig::Example1 => run(vec!["example1".into()]),
                RunConfig::Example3 { background } => {
                    run(vec!["example3".into(), "--bg".into(), background.to_string()])
                }
                RunConfig::Fig5 => run(vec!["fig5".into()]),
                RunConfig::E2e { jobs } => {
                    run(vec!["e2e".into(), "--jobs".into(), jobs.to_string()])
                }
                RunConfig::Scenario => {
                    let sweep = cfg.scenario.expect("scenario run carries its sweep");
                    run_scenario(&sweep, &path, &args, &cost)
                }
                RunConfig::Stream => {
                    let s = cfg.stream.expect("stream run carries its sweep");
                    let threads = opt(&args, "--threads")
                        .and_then(|x| x.parse().ok())
                        .map(|t: usize| t.max(1))
                        .unwrap_or(s.threads);
                    println!(
                        "== online stream sweep from {path} ({} rates, {} jobs, {threads} threads) ==",
                        s.rates.len(),
                        s.spec.jobs
                    );
                    print_stream_points(&run_stream_sweep_with(
                        &s.spec, &s.rates, &cost, threads,
                    ));
                    0
                }
                RunConfig::Table1 { .. } => {
                    println!("== Table I ({}) from {path} ==", cfg.table1.kind.label());
                    let rows = run_table1(&cfg.table1, &cost);
                    print!("{}", trace::table1_markdown(&rows));
                    0
                }
                RunConfig::Scale => {
                    let s = cfg.scale.expect("scale run carries its sweep");
                    let threads = opt(&args, "--threads")
                        .and_then(|x| x.parse().ok())
                        .map(|t: usize| t.max(1))
                        .unwrap_or(s.threads);
                    let hosts =
                        if s.hosts.is_empty() { None } else { Some(s.hosts.clone()) };
                    println!("(scale sweep from {path})");
                    run_scale_cmd(s.fat, hosts, s.shards, threads)
                }
                RunConfig::Soak => {
                    let s = cfg.soak.expect("soak run carries its load");
                    let threads = opt(&args, "--threads")
                        .and_then(|x| x.parse().ok())
                        .map(|t: usize| t.max(1))
                        .unwrap_or(s.threads);
                    println!(
                        "== sustained-load soak sweep from {path} ({} jobs, {} stages, \
                         {threads} threads) ==",
                        s.shape.total_jobs(),
                        s.shape.stages().len()
                    );
                    print_soak_points(&run_soak_sweep_with(
                        &s.shape,
                        s.seed,
                        s.policy(),
                        s.soak_config(),
                        &cost,
                        threads,
                    ));
                    0
                }
                RunConfig::Fairness => {
                    let f = cfg.fairness.expect("fairness run carries its sweep");
                    let threads = opt(&args, "--threads")
                        .and_then(|x| x.parse().ok())
                        .map(|t: usize| t.max(1))
                        .unwrap_or(f.threads);
                    println!(
                        "== multi-tenant fairness sweep from {path} ({} rates, {} jobs, \
                         {threads} threads) ==",
                        f.rates.len(),
                        f.jobs
                    );
                    let pts = match &f.tenants {
                        Some(tn) => {
                            run_fairness_sweep_with(tn, &f.rates, f.jobs, &cost, threads)
                        }
                        None => {
                            run_fairness_sweep(&f.weights, &f.rates, f.jobs, &cost, threads)
                        }
                    };
                    print_fairness_points(&pts);
                    0
                }
            }
        }
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            0
        }
        other => {
            eprintln!("unknown command {other:?}\n\n{HELP}");
            2
        }
    }
}

/// The `scale` sweep body shared by the subcommand and the `[scale]`
/// config route. `hosts` are total host counts (validated multiples of 8
/// — the grids have 8 leaves/switches); `shards` caps the controller's
/// shard plan on the fat grid.
fn run_scale_cmd(
    fat: bool,
    hosts: Option<Vec<usize>>,
    shards: Option<usize>,
    threads: usize,
) -> i32 {
    let cost = CostModel::rust_only();
    let pts = if fat {
        let per_edge: Vec<usize> = hosts
            .map(|v| v.iter().map(|h| h / 8).collect())
            .unwrap_or_else(|| vec![4, 16, 64, 128]);
        let max_hosts = per_edge.iter().map(|p| p * 8).max().unwrap_or(0);
        match shards {
            Some(n) => println!(
                "== scalability sweep (8-leaf fat tree up to {max_hosts} hosts, \
                 {n} shards, {threads} threads) =="
            ),
            None => println!(
                "== scalability sweep (8-leaf fat tree up to {max_hosts} hosts, \
                 {threads} threads) =="
            ),
        }
        run_scale_fat_with(
            &per_edge,
            &[SchedulerKind::Bass, SchedulerKind::Hds],
            shards,
            &cost,
            threads,
        )
    } else {
        println!("== scalability sweep (8 switches x N hosts, {threads} threads) ==");
        run_scale(&[2, 4, 8, 16], &cost, threads)
    };
    for p in pts {
        println!(
            "n={:<4} m={:<4} {:<5} sched {:>8.2}ms  makespan {:>7.1}s",
            p.nodes, p.tasks, p.scheduler, p.sched_secs * 1e3, p.makespan
        );
    }
    0
}

fn load_config(path: &str) -> Result<ExperimentConfig, i32> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("cannot read {path}: {e}");
            return Err(2);
        }
    };
    ExperimentConfig::from_str(&text).map_err(|e| {
        eprintln!("bad config {path}: {e}");
        2
    })
}

fn run_scenario(sweep: &ScenarioSweep, path: &str, args: &[String], cost: &CostModel) -> i32 {
    let threads = opt(args, "--threads")
        .and_then(|s| s.parse().ok())
        .map(|t: usize| t.max(1))
        .unwrap_or(sweep.base.threads);
    println!(
        "== scenario {} from {path} ({} points, {threads} threads) ==",
        sweep.base.name,
        sweep.sizes_mb.len() * sweep.schedulers.len()
    );
    if sweep.base.dynamics.is_some() || sweep.base.mitigation.is_some() {
        // churn route: each cell's map wave plays the [dynamics] timeline
        // (a bare [mitigation] table rides the same pipeline over an
        // empty timeline rather than being silently ignored)
        println!(
            "{:<10} {:>9} {:>10} {:>8} {:>9} {:>7} {:>7} {:>8} {:>10}",
            "scheduler", "size(MB)", "makespan", "LR", "reassign", "rounds", "defer",
            "underrep", "completed"
        );
        for r in run_dynamic_grid(sweep.points(), threads, cost) {
            println!(
                "{:<10} {:>9.0} {:>9.1}s {:>7.1}% {:>9} {:>7} {:>7} {:>8} {:>7}/{}",
                r.scheduler,
                r.data_mb,
                r.makespan,
                r.locality * 100.0,
                r.reassignments,
                r.rounds,
                r.deferrals,
                r.under_replicated_peak,
                r.completed,
                r.tasks
            );
        }
        return 0;
    }
    let rows = run_job_grid(sweep.points(), threads, cost);
    println!(
        "{:<10} {:>9} {:>8} {:>8} {:>8} {:>7}",
        "scheduler", "size(MB)", "MT(s)", "RT(s)", "JT(s)", "LR"
    );
    for r in &rows {
        println!(
            "{:<10} {:>9.0} {:>8.1} {:>8.1} {:>8.1} {:>6.1}%",
            r.scheduler,
            r.data_mb,
            r.metrics.mt,
            r.metrics.rt,
            r.metrics.jt,
            r.metrics.lr * 100.0
        );
    }
    0
}

fn parse_sizes(s: String) -> Vec<f64> {
    s.split(',').filter_map(|x| x.trim().parse().ok()).collect()
}

fn print_stream_points(pts: &[StreamPoint]) {
    println!(
        "{:<8} {:<5} {:>9} {:>9} {:>9} {:>9} {:>10} {:>7}",
        "gap(s)", "sched", "meanJT", "p50JT", "p95JT", "slowdown", "makespan", "queued"
    );
    for p in pts {
        println!(
            "{:<8.1} {:<5} {:>8.1}s {:>8.1}s {:>8.1}s {:>8.2}x {:>9.1}s {:>7}",
            p.mean_interarrival_secs,
            p.scheduler,
            p.mean_jt,
            p.p50_jt,
            p.p95_jt,
            p.mean_slowdown,
            p.makespan,
            p.queued
        );
    }
}

fn print_soak_points(pts: &[SoakPoint]) {
    println!(
        "{:<5} {:>6} {:>7} {:>9} {:>9} {:>9} {:>8} {:>8} {:>5} {:>8}",
        "sched", "jobs", "queued", "meanJT", "p95JT", "p95Slow", "jobs/h", "sust/h",
        "gc", "peakRec"
    );
    for p in pts {
        println!(
            "{:<5} {:>6} {:>7} {:>8.1}s {:>8.1}s {:>8.2}x {:>8.1} {:>8.1} {:>5} {:>8}",
            p.scheduler,
            p.jobs,
            p.queued,
            p.mean_jt,
            p.p95_jt,
            p.p95_slowdown,
            p.jobs_per_hour,
            p.sustained_jobs_per_hour,
            p.compactions,
            p.peak_live_records
        );
    }
}

fn print_fairness_points(pts: &[FairnessPoint]) {
    println!(
        "{:<8} {:<5} {:<8} {:>7} {:>6} {:>4} {:>9} {:>9} {:>6} {:>8} {:>6}",
        "gap(s)", "sched", "tenant", "weight", "jobs", "rej", "meanSlow", "p95Slow", "SLO",
        "preempt", "jain"
    );
    for p in pts {
        for t in &p.tenants {
            println!(
                "{:<8.1} {:<5} {:<8} {:>7.1} {:>6} {:>4} {:>8.2}x {:>8.2}x {:>5.0}% \
                 {:>8} {:>6.3}",
                p.mean_interarrival_secs,
                p.scheduler,
                t.tenant,
                t.weight,
                t.jobs,
                t.rejected,
                t.mean_slowdown,
                t.p95_slowdown,
                t.slo_attainment * 100.0,
                p.preemptions,
                p.fairness_jain
            );
        }
    }
}

fn apply_overrides(cfg: &mut Table1Config, args: &[String]) {
    if let Some(s) = opt(args, "--sizes") {
        let v = parse_sizes(s);
        if !v.is_empty() {
            cfg.sizes_mb = v;
        }
    }
    if let Some(s) = opt(args, "--sched") {
        let v: Vec<SchedulerKind> =
            s.split(',').filter_map(|x| SchedulerKind::parse(x.trim())).collect();
        if !v.is_empty() {
            cfg.schedulers = v;
        }
    }
    if let Some(s) = opt(args, "--seed").and_then(|s| s.parse().ok()) {
        cfg.seed = s;
    }
    if let Some(t) = opt(args, "--threads").and_then(|s| s.parse().ok()) {
        cfg.threads = std::cmp::max(t, 1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opt_parses_pairs() {
        let args: Vec<String> =
            ["table1", "--job", "sort", "--seed", "9"].iter().map(|s| s.to_string()).collect();
        assert_eq!(opt(&args, "--job").as_deref(), Some("sort"));
        assert_eq!(opt(&args, "--seed").as_deref(), Some("9"));
        assert_eq!(opt(&args, "--missing"), None);
    }

    #[test]
    fn parse_sizes_filters_garbage() {
        assert_eq!(parse_sizes("150, 300,x,600".into()), vec![150.0, 300.0, 600.0]);
    }

    #[test]
    fn unknown_command_fails() {
        assert_eq!(run(vec!["bogus".into()]), 2);
    }

    #[test]
    fn run_requires_config() {
        assert_eq!(run(vec!["run".into()]), 2);
        assert_eq!(run(vec!["run".into(), "--config".into(), "/no/such".into()]), 2);
        assert_eq!(run(vec!["scenario".into()]), 2);
    }

    #[test]
    fn run_with_config_file() {
        let dir = std::env::temp_dir().join("bass_cli_test");
        std::fs::create_dir_all(&dir).unwrap();
        let f = dir.join("exp.toml");
        std::fs::write(&f, "run = \"table1\"\njob = \"sort\"\n[sweep]\nsizes_mb = [150]\n").unwrap();
        assert_eq!(run(vec!["run".into(), "--config".into(), f.display().to_string()]), 0);
    }

    #[test]
    fn scenario_subcommand_runs_a_sweep_file() {
        let dir = std::env::temp_dir().join("bass_cli_scenario_test");
        std::fs::create_dir_all(&dir).unwrap();
        let f = dir.join("scenario.toml");
        std::fs::write(
            &f,
            "run = \"scenario\"\njob = \"sort\"\nthreads = 2\n\
             [sweep]\nsizes_mb = [150]\nschedulers = \"bass, hds\"\n",
        )
        .unwrap();
        assert_eq!(run(vec!["scenario".into(), "--config".into(), f.display().to_string()]), 0);
        // the generic `run` entry point accepts scenario files too
        assert_eq!(run(vec!["run".into(), "--config".into(), f.display().to_string()]), 0);
    }

    #[test]
    fn dynamics_subcommand_runs() {
        assert_eq!(run(vec!["dynamics".into(), "--levels".into(), "0,0.5".into()]), 0);
    }

    #[test]
    fn dynamics_subcommand_accepts_mitigation_modes() {
        for mode in ["off", "late", "bw_aware"] {
            let args: Vec<String> = ["dynamics", "--levels", "1", "--mitigation", mode]
                .iter()
                .map(|s| s.to_string())
                .collect();
            assert_eq!(run(args), 0, "--mitigation {mode}");
        }
    }

    #[test]
    fn dynamics_subcommand_rejects_bad_mitigation() {
        // same strictness as --reps/--rates: no silent unmitigated sweep
        for bad in ["bw-aware", "LATE", "speculate", ""] {
            let args: Vec<String> = ["dynamics", "--levels", "0", "--mitigation", bad]
                .iter()
                .map(|s| s.to_string())
                .collect();
            assert_eq!(run(args), 2, "--mitigation {bad:?}");
        }
    }

    #[test]
    fn estimate_subcommand_runs() {
        let args: Vec<String> =
            ["estimate", "--noises", "0,0.3", "--periods", "2", "--threads", "2"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        assert_eq!(run(args), 0);
    }

    #[test]
    fn estimate_subcommand_rejects_bad_axes() {
        // same strictness as --reps/--rates: no silent default sweep
        for (key, bad) in [
            ("--noises", "-0.1"),
            ("--noises", "abc"),
            ("--noises", "0.1,oops"),
            ("--periods", "-1"),
            ("--periods", "abc"),
        ] {
            let args: Vec<String> =
                ["estimate", key, bad].iter().map(|s| s.to_string()).collect();
            assert_eq!(run(args), 2, "{key} {bad}");
        }
    }

    #[test]
    fn scenario_with_telemetry_table_runs_and_rejects_typos() {
        let dir = std::env::temp_dir().join("bass_cli_telemetry_test");
        std::fs::create_dir_all(&dir).unwrap();
        let f = dir.join("telem.toml");
        std::fs::write(
            &f,
            "run = \"scenario\"\njob = \"sort\"\n\
             [sweep]\nsizes_mb = [150]\nschedulers = \"bass\"\n\
             [telemetry]\nprobe_period = 2\nnoise = 0.1\n",
        )
        .unwrap();
        assert_eq!(run(vec!["scenario".into(), "--config".into(), f.display().to_string()]), 0);
        // a typo'd [telemetry] key is rejected, not silently clairvoyant
        let bad = dir.join("bad.toml");
        std::fs::write(&bad, "run = \"scenario\"\n[telemetry]\nprobe_secs = 2\n").unwrap();
        assert_eq!(run(vec!["scenario".into(), "--config".into(), bad.display().to_string()]), 2);
    }

    #[test]
    fn skew_subcommand_runs_and_rejects_bad_reps() {
        let args: Vec<String> =
            ["skew", "--reps", "1", "--threads", "2"].iter().map(|s| s.to_string()).collect();
        assert_eq!(run(args), 0);
        for bad in ["0", "abc", "2,oops", "32"] {
            let args: Vec<String> =
                ["skew", "--reps", bad].iter().map(|s| s.to_string()).collect();
            assert_eq!(run(args), 2, "--reps {bad}");
        }
    }

    #[test]
    fn scale_subcommand_runs_a_small_fat_grid() {
        let args: Vec<String> =
            ["scale", "--fat", "--hosts", "16,32", "--shards", "2", "--threads", "2"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        assert_eq!(run(args), 0);
    }

    #[test]
    fn scale_subcommand_rejects_bad_knobs() {
        // same strictness as --reps/--rates: no silent default sweep
        for bad in [
            vec!["scale", "--fat", "--shards", "0"],
            vec!["scale", "--fat", "--shards", "abc"],
            vec!["scale", "--fat", "--hosts", "12"],
            vec!["scale", "--fat", "--hosts", "16,oops"],
            vec!["scale", "--shards", "4"], // requires --fat
            vec!["scale", "--hosts", "16"], // requires --fat
        ] {
            let args: Vec<String> = bad.iter().map(|s| s.to_string()).collect();
            assert_eq!(run(args), 2, "{bad:?}");
        }
    }

    #[test]
    fn scale_config_route_runs() {
        let dir = std::env::temp_dir().join("bass_cli_scale_test");
        std::fs::create_dir_all(&dir).unwrap();
        let f = dir.join("scale.toml");
        std::fs::write(
            &f,
            "run = \"scale\"\n[scale]\nfat = true\nhosts = [16]\nshards = 2\n",
        )
        .unwrap();
        assert_eq!(run(vec!["run".into(), "--config".into(), f.display().to_string()]), 0);
        // a typo'd [scale] key is rejected, not silently defaulted
        let bad = dir.join("bad.toml");
        std::fs::write(&bad, "run = \"scale\"\n[scale]\nshard = 2\n").unwrap();
        assert_eq!(run(vec!["run".into(), "--config".into(), bad.display().to_string()]), 2);
    }

    #[test]
    fn stream_subcommand_runs() {
        let args: Vec<String> =
            ["stream", "--rates", "40", "--jobs", "3", "--threads", "2"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        assert_eq!(run(args), 0);
    }

    #[test]
    fn stream_subcommand_rejects_bad_rates() {
        // same strictness as the [stream] table: no silent default sweep
        for bad in ["0", "-5", "abc", "60,oops"] {
            let args: Vec<String> = ["stream", "--rates", bad, "--jobs", "2"]
                .iter()
                .map(|s| s.to_string())
                .collect();
            assert_eq!(run(args), 2, "--rates {bad}");
        }
    }

    #[test]
    fn stream_config_route_runs() {
        let dir = std::env::temp_dir().join("bass_cli_stream_test");
        std::fs::create_dir_all(&dir).unwrap();
        let f = dir.join("stream.toml");
        std::fs::write(
            &f,
            "run = \"stream\"\nthreads = 2\n\
             [stream]\njobs = 3\nrates = [50]\nsizes_mb = [150]\nseed = 5\n",
        )
        .unwrap();
        assert_eq!(run(vec!["run".into(), "--config".into(), f.display().to_string()]), 0);
        // a typo'd [stream] key is rejected, not silently defaulted
        let bad = dir.join("bad.toml");
        std::fs::write(&bad, "run = \"stream\"\n[stream]\nrate = [50]\n").unwrap();
        assert_eq!(run(vec!["run".into(), "--config".into(), bad.display().to_string()]), 2);
    }

    #[test]
    fn fairness_subcommand_runs() {
        let args: Vec<String> =
            ["fairness", "--weights", "2", "--rates", "40", "--jobs", "2", "--threads", "2"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        assert_eq!(run(args), 0);
    }

    #[test]
    fn fairness_subcommand_rejects_bad_flags() {
        // same strictness as --reps/--rates: no silent default sweep
        for bad in [
            vec!["fairness", "--weights", "0"],
            vec!["fairness", "--weights", "-2"],
            vec!["fairness", "--weights", "abc"],
            vec!["fairness", "--weights", "2,oops"],
            vec!["fairness", "--rates", "0"],
            vec!["fairness", "--rates", "-5"],
            vec!["fairness", "--rates", "abc"],
            vec!["fairness", "--jobs", "0"],
            vec!["fairness", "--jobs", "abc"],
        ] {
            let args: Vec<String> = bad.iter().map(|s| s.to_string()).collect();
            assert_eq!(run(args), 2, "{bad:?}");
        }
    }

    #[test]
    fn soak_subcommand_runs() {
        let args: Vec<String> =
            ["soak", "--jobs", "4", "--gap", "20", "--seed", "7", "--threads", "2"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        assert_eq!(run(args), 0);
    }

    #[test]
    fn soak_subcommand_rejects_bad_flags() {
        // same strictness as --reps/--rates: no silent default sweep
        for bad in [
            vec!["soak", "--jobs", "0"],
            vec!["soak", "--jobs", "abc"],
            vec!["soak", "--gap", "0"],
            vec!["soak", "--gap", "-5"],
            vec!["soak", "--gap", "abc"],
            vec!["soak", "--seed", "1.5"],
            vec!["soak", "--target", "0.5"],
            vec!["soak", "--target", "abc"],
        ] {
            let args: Vec<String> = bad.iter().map(|s| s.to_string()).collect();
            assert_eq!(run(args), 2, "{bad:?}");
        }
    }

    #[test]
    fn soak_config_route_runs_and_rejects_typos() {
        let dir = std::env::temp_dir().join("bass_cli_soak_test");
        std::fs::create_dir_all(&dir).unwrap();
        let f = dir.join("soak.toml");
        std::fs::write(
            &f,
            "run = \"soak\"\nthreads = 2\n\
             [load]\nstages = \"warmup, steady\"\nsizes_mb = [150]\nseed = 7\n\
             gc_period_secs = 60\n\
             [load.warmup]\nshape = \"ramp\"\njobs = 2\ngap_secs = 40\nto_gap_secs = 20\n\
             [load.steady]\njobs = 2\ngap_secs = 25\n",
        )
        .unwrap();
        assert_eq!(run(vec!["run".into(), "--config".into(), f.display().to_string()]), 0);
        // a typo'd [load] key is rejected, not silently defaulted
        let bad = dir.join("bad.toml");
        std::fs::write(&bad, "run = \"soak\"\n[load]\njob = 4\n").unwrap();
        assert_eq!(run(vec!["run".into(), "--config".into(), bad.display().to_string()]), 2);
        // and [load] on a non-soak run is a cross-run error
        let bad2 = dir.join("bad2.toml");
        std::fs::write(&bad2, "run = \"stream\"\n[load]\njobs = 4\n").unwrap();
        assert_eq!(run(vec!["run".into(), "--config".into(), bad2.display().to_string()]), 2);
    }

    #[test]
    fn fairness_config_route_runs_and_rejects_typos() {
        let dir = std::env::temp_dir().join("bass_cli_fairness_test");
        std::fs::create_dir_all(&dir).unwrap();
        let f = dir.join("fair.toml");
        std::fs::write(
            &f,
            "run = \"fairness\"\nthreads = 2\n\
             [fairness]\nweights = [2]\nrates = [40]\njobs = 2\n",
        )
        .unwrap();
        assert_eq!(run(vec!["run".into(), "--config".into(), f.display().to_string()]), 0);
        // the [tenants] route replaces the built-in prod/batch pair
        let tn = dir.join("tenants.toml");
        std::fs::write(
            &tn,
            "run = \"fairness\"\n[fairness]\nrates = [40]\njobs = 2\n\
             [tenants]\nnames = \"gold, silver\"\n[tenants.gold]\nweight = 3\n\
             class = \"guaranteed\"\n",
        )
        .unwrap();
        assert_eq!(run(vec!["run".into(), "--config".into(), tn.display().to_string()]), 0);
        // a typo'd [fairness] or [tenants] key is rejected, not defaulted
        let bad = dir.join("bad.toml");
        std::fs::write(&bad, "run = \"fairness\"\n[fairness]\nweight = [2]\n").unwrap();
        assert_eq!(run(vec!["run".into(), "--config".into(), bad.display().to_string()]), 2);
        let bad2 = dir.join("bad2.toml");
        std::fs::write(
            &bad2,
            "run = \"fairness\"\n[tenants]\nnames = \"a\"\n[tenants.a]\nwieght = 2\n",
        )
        .unwrap();
        assert_eq!(run(vec!["run".into(), "--config".into(), bad2.display().to_string()]), 2);
    }

    #[test]
    fn scenario_with_dynamics_table_runs_the_churn_route() {
        let dir = std::env::temp_dir().join("bass_cli_dynamics_test");
        std::fs::create_dir_all(&dir).unwrap();
        let f = dir.join("dyn.toml");
        std::fs::write(
            &f,
            "run = \"scenario\"\njob = \"sort\"\n\
             [sweep]\nsizes_mb = [150]\nschedulers = \"bass, hds\"\n\
             [dynamics]\nnode_failures = 1\nmttr_secs = 30\nhorizon_secs = 40\n",
        )
        .unwrap();
        assert_eq!(run(vec!["scenario".into(), "--config".into(), f.display().to_string()]), 0);
    }

    #[test]
    fn scenario_with_mitigation_table_runs_and_rejects_typos() {
        let dir = std::env::temp_dir().join("bass_cli_mitigation_test");
        std::fs::create_dir_all(&dir).unwrap();
        let f = dir.join("mit.toml");
        std::fs::write(
            &f,
            "run = \"scenario\"\njob = \"sort\"\n\
             [sweep]\nsizes_mb = [150]\nschedulers = \"bass\"\n\
             [dynamics]\nstragglers = 2\nstraggle_factor = 4\nhorizon_secs = 40\n\
             [mitigation]\nspeculation = \"bw_aware\"\nslow_threshold = 1.5\n",
        )
        .unwrap();
        assert_eq!(run(vec!["scenario".into(), "--config".into(), f.display().to_string()]), 0);
        // a typo'd [mitigation] key is rejected, not silently defaulted
        let bad = dir.join("bad.toml");
        std::fs::write(&bad, "run = \"scenario\"\n[mitigation]\nspeculate = \"late\"\n").unwrap();
        assert_eq!(run(vec!["scenario".into(), "--config".into(), bad.display().to_string()]), 2);
    }

    #[test]
    fn scenario_rejects_non_scenario_files() {
        let dir = std::env::temp_dir().join("bass_cli_scenario_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let f = dir.join("exp.toml");
        std::fs::write(&f, "run = \"table1\"\n").unwrap();
        assert_eq!(run(vec!["scenario".into(), "--config".into(), f.display().to_string()]), 2);
    }

    #[test]
    fn overrides_apply() {
        let mut cfg = Table1Config::paper(JobKind::Wordcount);
        let args: Vec<String> =
            ["--sizes", "150", "--sched", "bass,hds", "--seed", "42", "--threads", "3"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        apply_overrides(&mut cfg, &args);
        assert_eq!(cfg.sizes_mb, vec![150.0]);
        assert_eq!(cfg.schedulers.len(), 2);
        assert_eq!(cfg.seed, 42);
        assert_eq!(cfg.threads, 3);
    }
}
