//! Background load injection (Section V-A: "we repetitively execute a
//! background job to provide each test with initial workload").
//!
//! Two effects, matching the paper's shared-cluster conditions:
//!
//! 1. **Initial node workload** — every node starts with a random busy
//!    window (the `ΥI` the ProgressRate estimator would report).
//! 2. **Background traffic** — long-running flows on random host pairs
//!    that both (a) reduce the `BW_rl` the SDN controller reports and
//!    (b) contend with fair-share transfers in the flow network.

use crate::sdn::{Controller, TrafficClass};
use crate::sim::FlowNet;
use crate::topology::NodeId;
use crate::util::{Secs, XorShift};

/// Deterministic background-load plan.
#[derive(Debug, Clone)]
pub struct BackgroundLoad {
    /// Initial busy time per node (seconds).
    pub initial_idle: Vec<Secs>,
    /// Host pairs carrying permanent background flows.
    pub flows: Vec<(NodeId, NodeId)>,
    /// Per-flow nominal rate for the controller's static view (MB/s).
    pub flow_rate_mb_s: f64,
}

impl BackgroundLoad {
    /// Sample a plan: idle in `[0, max_idle)`, `n_flows` random distinct
    /// host pairs.
    pub fn sample(
        nodes: &[NodeId],
        max_idle: f64,
        n_flows: usize,
        flow_rate_mb_s: f64,
        rng: &mut XorShift,
    ) -> Self {
        let initial_idle =
            nodes.iter().map(|_| Secs(rng.uniform(0.0, max_idle.max(1e-9)))).collect();
        let mut flows = Vec::with_capacity(n_flows);
        for _ in 0..n_flows {
            let picks = rng.distinct(nodes.len(), 2.min(nodes.len()));
            if picks.len() == 2 {
                flows.push((nodes[picks[0]], nodes[picks[1]]));
            }
        }
        Self { initial_idle, flows, flow_rate_mb_s }
    }

    /// No background at all (Example 1 uses explicit idle times instead).
    pub fn none(nodes: &[NodeId]) -> Self {
        Self {
            initial_idle: nodes.iter().map(|_| Secs::ZERO).collect(),
            flows: Vec::new(),
            flow_rate_mb_s: 0.0,
        }
    }

    /// Install the static view into the controller (what `BW_rl` reports)
    /// and the live flows into the flow network (what HDS/BAR feel).
    pub fn install(&self, ctrl: &mut Controller, net: &mut FlowNet) {
        for &(a, b) in &self.flows {
            if let Some(path) = ctrl.path(a, b).map(|p| p.to_vec()) {
                for l in &path {
                    let cur = ctrl.background_mb_s(*l);
                    ctrl.set_background_mb_s(*l, cur + self.flow_rate_mb_s);
                }
                net.add_background_capped(path, TrafficClass::Background, self.flow_rate_mb_s);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::builders::tree_cluster;

    #[test]
    fn sample_is_deterministic_and_bounded() {
        let nodes: Vec<NodeId> = (0..6).map(NodeId).collect();
        let mut r1 = XorShift::new(5);
        let mut r2 = XorShift::new(5);
        let a = BackgroundLoad::sample(&nodes, 30.0, 3, 2.0, &mut r1);
        let b = BackgroundLoad::sample(&nodes, 30.0, 3, 2.0, &mut r2);
        assert_eq!(a.initial_idle, b.initial_idle);
        assert_eq!(a.flows, b.flows);
        assert!(a.initial_idle.iter().all(|s| s.0 < 30.0));
        assert_eq!(a.flows.len(), 3);
    }

    #[test]
    fn install_reduces_controller_bw_and_adds_flows() {
        let (topo, nodes) = tree_cluster(2, 3, 100.0, 100.0);
        let mut ctrl = Controller::new(topo, 1.0);
        let caps: Vec<f64> =
            (0..ctrl.topo().n_links()).map(|_| 100.0).collect();
        let mut net = FlowNet::new(&caps);
        let bg = BackgroundLoad {
            initial_idle: nodes.iter().map(|_| Secs::ZERO).collect(),
            flows: vec![(nodes[0], nodes[5])],
            flow_rate_mb_s: 4.0,
        };
        let before = ctrl.path_bw_mb_s(nodes[0], nodes[5], Secs::ZERO);
        bg.install(&mut ctrl, &mut net);
        let after = ctrl.path_bw_mb_s(nodes[0], nodes[5], Secs::ZERO);
        assert!(after < before);
        assert_eq!(net.n_flows(), 1);
    }
}
