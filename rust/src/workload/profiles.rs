//! Wordcount / Sort job profiles (Section V-A).
//!
//! The paper: "We choose Wordcount and Sort for test because the former
//! consumes more CPU while the latter occupies more disk I/O". In the
//! model that translates to:
//!
//! * **Wordcount** — long map compute, small map output (word histograms
//!   shrink data), modest reduces.
//! * **Sort** — short map compute (identity map), full-size map output
//!   (shuffle ≈ input), long reduces (merge + write).
//!
//! Per-task durations are calibrated so the *HDS baseline* lands in the
//! neighbourhood of Table I's HDS column; the BASS/BAR deltas then come
//! entirely out of scheduling, which is what the reproduction tests.

use crate::hdfs::{Namenode, PlacementPolicy};
use crate::mapreduce::{JobSpec, TaskSpec};
use crate::topology::NodeId;
use crate::util::{Secs, XorShift, BLOCK_MB};

/// Which of the paper's two jobs to generate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobKind {
    Wordcount,
    Sort,
}

impl JobKind {
    pub fn label(&self) -> &'static str {
        match self {
            JobKind::Wordcount => "wordcount",
            JobKind::Sort => "sort",
        }
    }

    /// Map compute seconds per 64MB block.
    fn map_compute(&self) -> f64 {
        match self {
            JobKind::Wordcount => 22.0, // CPU-bound
            JobKind::Sort => 7.0,       // identity map
        }
    }

    /// Map output volume as a fraction of the input split.
    fn shuffle_ratio(&self) -> f64 {
        match self {
            JobKind::Wordcount => 0.25,
            JobKind::Sort => 1.0,
        }
    }

    /// Reduce compute seconds per MB of shuffle input.
    fn reduce_compute_per_mb(&self) -> f64 {
        match self {
            JobKind::Wordcount => 0.35,
            JobKind::Sort => 0.55, // disk-bound merge
        }
    }
}

/// Builds jobs + HDFS layout for a cluster.
pub struct WorkloadBuilder {
    pub kind: JobKind,
    pub replication: usize,
    pub reduces: usize,
    pub placement: PlacementPolicy,
    /// Rack of each node in the slice handed to [`WorkloadBuilder::build`]
    /// (empty = flat cluster; only the rack-aware policy reads it).
    pub racks: Vec<usize>,
}

impl WorkloadBuilder {
    pub fn new(kind: JobKind) -> Self {
        Self {
            kind,
            replication: 3,
            reduces: 2,
            placement: PlacementPolicy::RandomDistinct,
            racks: Vec::new(),
        }
    }

    /// Number of 64MB blocks for a data size (the paper's sweep points).
    pub fn n_blocks(data_mb: f64) -> usize {
        (data_mb / BLOCK_MB).ceil().max(1.0) as usize
    }

    /// Generate the job: places blocks into `nn` and returns the spec.
    /// Map tasks 0..b, reduce tasks b..b+r (src hints filled later by the
    /// experiment driver once map placements are known).
    pub fn build(
        &self,
        job_id: usize,
        data_mb: f64,
        nodes: &[NodeId],
        nn: &mut Namenode,
        rng: &mut XorShift,
    ) -> JobSpec {
        let b = Self::n_blocks(data_mb);
        let blocks = self.placement.place(
            nn,
            nodes,
            &self.racks,
            b,
            BLOCK_MB,
            self.replication.min(nodes.len()),
            rng,
        );
        let mut tasks = Vec::with_capacity(b + self.reduces);
        for (i, &blk) in blocks.iter().enumerate() {
            tasks.push(TaskSpec::map(
                i,
                blk,
                BLOCK_MB,
                Secs(self.kind.map_compute()),
                BLOCK_MB * self.kind.shuffle_ratio(),
            ));
        }
        let shuffle_total = b as f64 * BLOCK_MB * self.kind.shuffle_ratio();
        let per_reduce = shuffle_total / self.reduces.max(1) as f64;
        for r in 0..self.reduces {
            tasks.push(TaskSpec::reduce(
                b + r,
                per_reduce,
                Secs(per_reduce * self.kind.reduce_compute_per_mb()),
            ));
        }
        JobSpec::new(job_id, format!("{}-{}MB", self.kind.label(), data_mb as u64), tasks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nodes6() -> Vec<NodeId> {
        (0..6).map(NodeId).collect()
    }

    #[test]
    fn block_counts_match_paper_sizes() {
        assert_eq!(WorkloadBuilder::n_blocks(150.0), 3);
        assert_eq!(WorkloadBuilder::n_blocks(300.0), 5);
        assert_eq!(WorkloadBuilder::n_blocks(600.0), 10);
        assert_eq!(WorkloadBuilder::n_blocks(1024.0), 16);
        assert_eq!(WorkloadBuilder::n_blocks(5120.0), 80);
    }

    #[test]
    fn wordcount_job_shape() {
        let mut nn = Namenode::new();
        let mut rng = XorShift::new(1);
        let j = WorkloadBuilder::new(JobKind::Wordcount)
            .build(0, 600.0, &nodes6(), &mut nn, &mut rng);
        assert_eq!(j.n_maps(), 10);
        assert_eq!(j.n_reduces(), 2);
        assert_eq!(nn.n_blocks(), 10);
        // shuffle shrinks for wordcount
        assert!(j.shuffle_volume_mb() < 600.0 * 0.5);
    }

    #[test]
    fn sort_shuffles_everything() {
        let mut nn = Namenode::new();
        let mut rng = XorShift::new(1);
        let j = WorkloadBuilder::new(JobKind::Sort).build(0, 600.0, &nodes6(), &mut nn, &mut rng);
        assert!((j.shuffle_volume_mb() - 640.0).abs() < 1e-9); // 10 blocks x 64MB
        // sort maps are cheap, reduces expensive
        let map_tp = j.maps().next().unwrap().compute.0;
        let red_tp = j.reduces().next().unwrap().compute.0;
        assert!(red_tp > map_tp);
    }

    #[test]
    fn deterministic_given_seed() {
        let gen = |seed| {
            let mut nn = Namenode::new();
            let mut rng = XorShift::new(seed);
            let j = WorkloadBuilder::new(JobKind::Sort)
                .build(0, 300.0, &nodes6(), &mut nn, &mut rng);
            (0..nn.n_blocks())
                .map(|b| nn.block(crate::hdfs::BlockId(b)).replicas.clone())
                .collect::<Vec<_>>()
        };
        assert_eq!(gen(9), gen(9));
    }
}
