//! Synthetic job-arrival traces for the end-to-end driver.

use crate::util::XorShift;

use super::profiles::JobKind;

/// One job arrival in a trace.
#[derive(Debug, Clone)]
pub struct JobArrival {
    pub at_secs: f64,
    pub kind: JobKind,
    pub data_mb: f64,
}

/// Poisson-ish (geometric inter-arrival) trace generator.
#[derive(Debug, Clone)]
pub struct TraceGen {
    pub mean_interarrival_secs: f64,
    pub sizes_mb: Vec<f64>,
}

impl Default for TraceGen {
    fn default() -> Self {
        Self { mean_interarrival_secs: 60.0, sizes_mb: vec![150.0, 300.0, 600.0] }
    }
}

impl TraceGen {
    /// Generate `n` arrivals, deterministic for a seed.
    pub fn generate(&self, n: usize, rng: &mut XorShift) -> Vec<JobArrival> {
        let mut t = 0.0;
        (0..n)
            .map(|_| {
                t += rng.uniform(0.2, 1.8) * self.mean_interarrival_secs;
                JobArrival {
                    at_secs: t,
                    kind: if rng.chance(0.5) { JobKind::Wordcount } else { JobKind::Sort },
                    data_mb: self.sizes_mb[rng.below(self.sizes_mb.len())],
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_monotone_and_deterministic() {
        let g = TraceGen::default();
        let mut r1 = XorShift::new(3);
        let mut r2 = XorShift::new(3);
        let a = g.generate(20, &mut r1);
        let b = g.generate(20, &mut r2);
        assert_eq!(a.len(), 20);
        for w in a.windows(2) {
            assert!(w[0].at_secs < w[1].at_secs);
        }
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at_secs, y.at_secs);
            assert_eq!(x.data_mb, y.data_mb);
        }
    }

    #[test]
    fn sizes_come_from_menu() {
        let g = TraceGen::default();
        let mut r = XorShift::new(7);
        for a in g.generate(50, &mut r) {
            assert!(g.sizes_mb.contains(&a.data_mb));
        }
    }
}
