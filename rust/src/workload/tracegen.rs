//! Synthetic job-arrival traces for the end-to-end driver.

use crate::util::XorShift;

use super::profiles::JobKind;

/// One job arrival in a trace.
#[derive(Debug, Clone)]
pub struct JobArrival {
    pub at_secs: f64,
    pub kind: JobKind,
    pub data_mb: f64,
}

/// Poisson-ish (geometric inter-arrival) trace generator.
#[derive(Debug, Clone)]
pub struct TraceGen {
    pub mean_interarrival_secs: f64,
    pub sizes_mb: Vec<f64>,
}

impl Default for TraceGen {
    fn default() -> Self {
        Self { mean_interarrival_secs: 60.0, sizes_mb: vec![150.0, 300.0, 600.0] }
    }
}

impl TraceGen {
    /// Generate `n` arrivals, deterministic for a seed.
    pub fn generate(&self, n: usize, rng: &mut XorShift) -> Vec<JobArrival> {
        let mut t = 0.0;
        (0..n)
            .map(|_| {
                t += rng.uniform(0.2, 1.8) * self.mean_interarrival_secs;
                JobArrival {
                    at_secs: t,
                    kind: if rng.chance(0.5) { JobKind::Wordcount } else { JobKind::Sort },
                    data_mb: self.sizes_mb[rng.below(self.sizes_mb.len())],
                }
            })
            .collect()
    }

    /// Generate `n` arrivals with true exponential inter-arrival gaps (a
    /// Poisson arrival process of rate `1 / mean_interarrival_secs`) —
    /// the arrival model of the online stream sweeps
    /// (`experiments::stream`). Unlike [`TraceGen::generate`]'s bounded
    /// gaps, exponential gaps produce the bursts that make overlapping
    /// jobs contend. Deterministic for a seed; arrivals stay strictly
    /// increasing (gaps are floored just above zero).
    pub fn generate_poisson(&self, n: usize, rng: &mut XorShift) -> Vec<JobArrival> {
        let mut t = 0.0;
        (0..n)
            .map(|_| {
                // inverse-CDF sample; uniform is [0, 1) so 1-u is (0, 1]
                let u = rng.uniform(0.0, 1.0);
                t += (-(1.0 - u).ln()).max(1e-9) * self.mean_interarrival_secs;
                JobArrival {
                    at_secs: t,
                    kind: if rng.chance(0.5) { JobKind::Wordcount } else { JobKind::Sort },
                    data_mb: self.sizes_mb[rng.below(self.sizes_mb.len())],
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_monotone_and_deterministic() {
        let g = TraceGen::default();
        let mut r1 = XorShift::new(3);
        let mut r2 = XorShift::new(3);
        let a = g.generate(20, &mut r1);
        let b = g.generate(20, &mut r2);
        assert_eq!(a.len(), 20);
        for w in a.windows(2) {
            assert!(w[0].at_secs < w[1].at_secs);
        }
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at_secs, y.at_secs);
            assert_eq!(x.data_mb, y.data_mb);
        }
    }

    #[test]
    fn poisson_arrivals_are_monotone_deterministic_and_mean_scaled() {
        let g = TraceGen { mean_interarrival_secs: 30.0, sizes_mb: vec![150.0] };
        let a = g.generate_poisson(200, &mut XorShift::new(5));
        let b = g.generate_poisson(200, &mut XorShift::new(5));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at_secs, y.at_secs);
        }
        for w in a.windows(2) {
            assert!(w[0].at_secs < w[1].at_secs);
        }
        // LLN sanity: the empirical mean gap is within 30% of the mean
        let mean = a.last().unwrap().at_secs / 200.0;
        assert!((mean - 30.0).abs() < 9.0, "empirical mean gap {mean}");
    }

    #[test]
    fn sizes_come_from_menu() {
        let g = TraceGen::default();
        let mut r = XorShift::new(7);
        for a in g.generate(50, &mut r) {
            assert!(g.sizes_mb.contains(&a.data_mb));
        }
    }
}
