//! Synthetic job-arrival traces for the end-to-end driver, plus the
//! staged [`LoadShape`] generator behind the soak pipeline: composable
//! ramp/spike/soak/concentrated stages over Poisson inter-arrival
//! processes, optional diurnal rate modulation, and heavy-tailed
//! (truncated Pareto) job sizes. Everything is seeded and
//! deterministic; the single-stage soak shape over a size menu draws
//! from the RNG in exactly the order [`TraceGen::generate_poisson`]
//! always has, so the existing stream sweeps stay bit-identical.

use crate::util::XorShift;

use super::profiles::JobKind;

/// One job arrival in a trace.
#[derive(Debug, Clone)]
pub struct JobArrival {
    pub at_secs: f64,
    pub kind: JobKind,
    pub data_mb: f64,
}

/// Poisson-ish (geometric inter-arrival) trace generator.
#[derive(Debug, Clone)]
pub struct TraceGen {
    pub mean_interarrival_secs: f64,
    pub sizes_mb: Vec<f64>,
}

impl Default for TraceGen {
    fn default() -> Self {
        Self { mean_interarrival_secs: 60.0, sizes_mb: vec![150.0, 300.0, 600.0] }
    }
}

impl TraceGen {
    /// Generate `n` arrivals, deterministic for a seed.
    pub fn generate(&self, n: usize, rng: &mut XorShift) -> Vec<JobArrival> {
        let mut t = 0.0;
        (0..n)
            .map(|_| {
                t += rng.uniform(0.2, 1.8) * self.mean_interarrival_secs;
                JobArrival {
                    at_secs: t,
                    kind: if rng.chance(0.5) { JobKind::Wordcount } else { JobKind::Sort },
                    data_mb: self.sizes_mb[rng.below(self.sizes_mb.len())],
                }
            })
            .collect()
    }

    /// Generate `n` arrivals with true exponential inter-arrival gaps (a
    /// Poisson arrival process of rate `1 / mean_interarrival_secs`) —
    /// the arrival model of the online stream sweeps
    /// (`experiments::stream`). Unlike [`TraceGen::generate`]'s bounded
    /// gaps, exponential gaps produce the bursts that make overlapping
    /// jobs contend. Deterministic for a seed; arrivals stay strictly
    /// increasing (gaps are floored just above zero).
    ///
    /// This is the trivial single-stage [`LoadShape`]: one soak stage
    /// over the size menu, no diurnal modulation. Degenerate inputs
    /// (non-positive/non-finite mean gap, empty or non-positive size
    /// menu, zero jobs) panic with a clear message instead of silently
    /// producing a broken trace — the config/CLI layers validate first,
    /// so a panic here is a caller bug.
    pub fn generate_poisson(&self, n: usize, rng: &mut XorShift) -> Vec<JobArrival> {
        let shape = LoadShape::poisson(n, self.mean_interarrival_secs, self.sizes_mb.clone())
            .unwrap_or_else(|e| panic!("TraceGen::generate_poisson: {e}"));
        shape.generate(rng)
    }
}

/// How a stage spaces its arrivals around the Poisson draws.
#[derive(Debug, Clone, PartialEq)]
pub enum StageShape {
    /// Constant mean gap — the plain Poisson process.
    Soak,
    /// Mean gap interpolates linearly from the stage's `mean_gap_secs`
    /// to `to_gap_secs` across the stage's arrivals (a load ramp when
    /// the gap shrinks, a cooldown when it grows).
    Ramp { to_gap_secs: f64 },
    /// Mean gap divided by `factor` (> 1 compresses the stage into a
    /// burst at `factor` times the base rate).
    Spike { factor: f64 },
    /// The whole stage lands inside roughly `within_secs`: the mean gap
    /// is `within_secs / jobs`, so all arrivals hit as one batch.
    Concentrated { within_secs: f64 },
}

/// One stage of a [`LoadShape`]: `jobs` Poisson arrivals whose mean
/// gap is derived from `mean_gap_secs` by the stage's [`StageShape`].
#[derive(Debug, Clone, PartialEq)]
pub struct LoadStage {
    pub jobs: usize,
    pub mean_gap_secs: f64,
    pub shape: StageShape,
}

impl LoadStage {
    pub fn soak(jobs: usize, mean_gap_secs: f64) -> Self {
        Self { jobs, mean_gap_secs, shape: StageShape::Soak }
    }

    pub fn ramp(jobs: usize, from_gap_secs: f64, to_gap_secs: f64) -> Self {
        Self { jobs, mean_gap_secs: from_gap_secs, shape: StageShape::Ramp { to_gap_secs } }
    }

    pub fn spike(jobs: usize, mean_gap_secs: f64, factor: f64) -> Self {
        Self { jobs, mean_gap_secs, shape: StageShape::Spike { factor } }
    }

    pub fn concentrated(jobs: usize, within_secs: f64) -> Self {
        // mean_gap_secs is unused by the shape but kept positive so the
        // shared validation holds for every stage uniformly
        Self { jobs, mean_gap_secs: within_secs, shape: StageShape::Concentrated { within_secs } }
    }
}

/// Job-size distribution: the menu the classic sweeps use, or a
/// truncated Pareto for heavy-tailed realism.
#[derive(Debug, Clone, PartialEq)]
pub enum SizeDist {
    /// Uniform pick from a fixed size menu (MB).
    Menu(Vec<f64>),
    /// Truncated Pareto: `P(X > x) = (min_mb / x)^alpha` for
    /// `min_mb <= x < cap_mb`, all mass above `cap_mb` collapsed onto
    /// `cap_mb` (inverse-CDF sample, one uniform draw per arrival).
    Pareto { alpha: f64, min_mb: f64, cap_mb: f64 },
}

/// Sinusoidal rate modulation on top of the stage schedule: the
/// instantaneous arrival rate is scaled by
/// `1 + amplitude * sin(2π t / period_secs)` — a day/night curve when
/// the period is long against the stage lengths.
#[derive(Debug, Clone, PartialEq)]
pub struct Diurnal {
    pub amplitude: f64,
    pub period_secs: f64,
}

/// A staged, seeded arrival-trace generator: stages run back to back on
/// one clock and one RNG cursor, so a shape is as deterministic as a
/// single Poisson trace. Construct through [`LoadShape::new`] /
/// [`LoadShape::poisson`] — both reject degenerate inputs
/// (non-positive gaps, empty stages, unusable size distributions)
/// instead of generating a broken trace.
#[derive(Debug, Clone, PartialEq)]
pub struct LoadShape {
    stages: Vec<LoadStage>,
    sizes: SizeDist,
    diurnal: Option<Diurnal>,
}

impl LoadShape {
    /// Validated constructor; every stage and the size distribution are
    /// checked here so `generate` cannot produce a degenerate trace.
    pub fn new(
        stages: Vec<LoadStage>,
        sizes: SizeDist,
        diurnal: Option<Diurnal>,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(!stages.is_empty(), "load shape needs at least one stage");
        for (i, st) in stages.iter().enumerate() {
            anyhow::ensure!(st.jobs >= 1, "stage {i}: jobs must be >= 1");
            anyhow::ensure!(
                st.mean_gap_secs > 0.0 && st.mean_gap_secs.is_finite(),
                "stage {i}: mean gap must be a positive number of seconds, got {}",
                st.mean_gap_secs
            );
            match st.shape {
                StageShape::Soak => {}
                StageShape::Ramp { to_gap_secs } => anyhow::ensure!(
                    to_gap_secs > 0.0 && to_gap_secs.is_finite(),
                    "stage {i}: ramp target gap must be positive, got {to_gap_secs}"
                ),
                StageShape::Spike { factor } => anyhow::ensure!(
                    factor >= 1.0 && factor.is_finite(),
                    "stage {i}: spike factor must be >= 1, got {factor}"
                ),
                StageShape::Concentrated { within_secs } => anyhow::ensure!(
                    within_secs > 0.0 && within_secs.is_finite(),
                    "stage {i}: concentration window must be positive, got {within_secs}"
                ),
            }
        }
        match &sizes {
            SizeDist::Menu(v) => {
                anyhow::ensure!(!v.is_empty(), "size menu must not be empty");
                for &s in v {
                    anyhow::ensure!(
                        s > 0.0 && s.is_finite(),
                        "size menu entries must be positive MB, got {s}"
                    );
                }
            }
            SizeDist::Pareto { alpha, min_mb, cap_mb } => {
                anyhow::ensure!(
                    *alpha > 0.0 && alpha.is_finite(),
                    "pareto alpha must be positive, got {alpha}"
                );
                anyhow::ensure!(
                    *min_mb > 0.0 && min_mb.is_finite(),
                    "pareto min size must be positive MB, got {min_mb}"
                );
                anyhow::ensure!(
                    *cap_mb >= *min_mb && cap_mb.is_finite(),
                    "pareto cap must be >= min size, got cap {cap_mb} < min {min_mb}"
                );
            }
        }
        if let Some(d) = &diurnal {
            anyhow::ensure!(
                (0.0..1.0).contains(&d.amplitude),
                "diurnal amplitude must be in [0, 1) so the rate stays positive, got {}",
                d.amplitude
            );
            anyhow::ensure!(
                d.period_secs > 0.0 && d.period_secs.is_finite(),
                "diurnal period must be positive seconds, got {}",
                d.period_secs
            );
        }
        Ok(Self { stages, sizes, diurnal })
    }

    /// The trivial single-stage shape: `jobs` soak arrivals at
    /// `mean_gap_secs` over a size menu — bit-identical to the classic
    /// [`TraceGen::generate_poisson`] trace for the same RNG.
    pub fn poisson(jobs: usize, mean_gap_secs: f64, sizes_mb: Vec<f64>) -> anyhow::Result<Self> {
        Self::new(vec![LoadStage::soak(jobs, mean_gap_secs)], SizeDist::Menu(sizes_mb), None)
    }

    pub fn stages(&self) -> &[LoadStage] {
        &self.stages
    }

    /// Total arrivals across all stages.
    pub fn total_jobs(&self) -> usize {
        self.stages.iter().map(|s| s.jobs).sum()
    }

    /// Play every stage back to back on one clock. Per arrival the RNG
    /// draw order is fixed — gap uniform, kind coin, size draw — which
    /// is exactly the old `generate_poisson` order, so the single-soak
    /// menu shape reproduces it bit for bit. Arrivals stay strictly
    /// increasing (gaps floored just above zero).
    pub fn generate(&self, rng: &mut XorShift) -> Vec<JobArrival> {
        let mut out = Vec::with_capacity(self.total_jobs());
        let mut t = 0.0f64;
        for st in &self.stages {
            for j in 0..st.jobs {
                // inverse-CDF sample; uniform is [0, 1) so 1-u is (0, 1]
                let u = rng.uniform(0.0, 1.0);
                let mut gap_mean = match st.shape {
                    StageShape::Soak => st.mean_gap_secs,
                    StageShape::Ramp { to_gap_secs } => {
                        let frac =
                            if st.jobs > 1 { j as f64 / (st.jobs - 1) as f64 } else { 0.0 };
                        st.mean_gap_secs + (to_gap_secs - st.mean_gap_secs) * frac
                    }
                    StageShape::Spike { factor } => st.mean_gap_secs / factor,
                    StageShape::Concentrated { within_secs } => within_secs / st.jobs as f64,
                };
                if let Some(d) = &self.diurnal {
                    // modulate the *rate*, so the gap divides; amplitude
                    // < 1 keeps the denominator strictly positive
                    let phase = 2.0 * std::f64::consts::PI * t / d.period_secs;
                    gap_mean /= 1.0 + d.amplitude * phase.sin();
                }
                t += (-(1.0 - u).ln()).max(1e-9) * gap_mean;
                let kind = if rng.chance(0.5) { JobKind::Wordcount } else { JobKind::Sort };
                let data_mb = match &self.sizes {
                    SizeDist::Menu(sizes) => sizes[rng.below(sizes.len())],
                    SizeDist::Pareto { alpha, min_mb, cap_mb } => {
                        let v = rng.uniform(0.0, 1.0);
                        (min_mb / (1.0 - v).powf(1.0 / alpha)).min(*cap_mb)
                    }
                };
                out.push(JobArrival { at_secs: t, kind, data_mb });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_monotone_and_deterministic() {
        let g = TraceGen::default();
        let mut r1 = XorShift::new(3);
        let mut r2 = XorShift::new(3);
        let a = g.generate(20, &mut r1);
        let b = g.generate(20, &mut r2);
        assert_eq!(a.len(), 20);
        for w in a.windows(2) {
            assert!(w[0].at_secs < w[1].at_secs);
        }
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at_secs, y.at_secs);
            assert_eq!(x.data_mb, y.data_mb);
        }
    }

    #[test]
    fn poisson_arrivals_are_monotone_deterministic_and_mean_scaled() {
        let g = TraceGen { mean_interarrival_secs: 30.0, sizes_mb: vec![150.0] };
        let a = g.generate_poisson(200, &mut XorShift::new(5));
        let b = g.generate_poisson(200, &mut XorShift::new(5));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at_secs, y.at_secs);
        }
        for w in a.windows(2) {
            assert!(w[0].at_secs < w[1].at_secs);
        }
        // LLN sanity: the empirical mean gap is within 30% of the mean
        let mean = a.last().unwrap().at_secs / 200.0;
        assert!((mean - 30.0).abs() < 9.0, "empirical mean gap {mean}");
    }

    #[test]
    fn sizes_come_from_menu() {
        let g = TraceGen::default();
        let mut r = XorShift::new(7);
        for a in g.generate(50, &mut r) {
            assert!(g.sizes_mb.contains(&a.data_mb));
        }
    }

    /// The single-soak menu shape must replay the exact draw sequence of
    /// the pre-refactor `generate_poisson` loop, kept inline here as the
    /// bitwise reference.
    #[test]
    fn single_stage_soak_is_bitwise_identical_to_the_old_poisson_loop() {
        let mean = 42.0;
        let sizes = [150.0, 300.0, 600.0];
        let mut reference = Vec::new();
        let mut rng = XorShift::new(4242);
        let mut t = 0.0f64;
        for _ in 0..64 {
            let u = rng.uniform(0.0, 1.0);
            t += (-(1.0 - u).ln()).max(1e-9) * mean;
            let kind = if rng.chance(0.5) { JobKind::Wordcount } else { JobKind::Sort };
            let data_mb = sizes[rng.below(sizes.len())];
            reference.push((t, kind, data_mb));
        }
        let shape = LoadShape::poisson(64, mean, sizes.to_vec()).unwrap();
        let got = shape.generate(&mut XorShift::new(4242));
        let via_tracegen = TraceGen { mean_interarrival_secs: mean, sizes_mb: sizes.to_vec() }
            .generate_poisson(64, &mut XorShift::new(4242));
        assert_eq!(got.len(), reference.len());
        for ((a, b), c) in got.iter().zip(&reference).zip(&via_tracegen) {
            assert_eq!(a.at_secs.to_bits(), b.0.to_bits());
            assert_eq!(a.kind, b.1);
            assert_eq!(a.data_mb.to_bits(), b.2.to_bits());
            assert_eq!(a.at_secs.to_bits(), c.at_secs.to_bits());
            assert_eq!(a.data_mb.to_bits(), c.data_mb.to_bits());
        }
    }

    #[test]
    fn multi_stage_shapes_are_seed_deterministic_and_monotone() {
        let shape = LoadShape::new(
            vec![
                LoadStage::ramp(40, 60.0, 10.0),
                LoadStage::spike(30, 20.0, 4.0),
                LoadStage::soak(80, 30.0),
                LoadStage::concentrated(20, 15.0),
            ],
            SizeDist::Pareto { alpha: 1.5, min_mb: 100.0, cap_mb: 2000.0 },
            Some(Diurnal { amplitude: 0.4, period_secs: 3600.0 }),
        )
        .unwrap();
        assert_eq!(shape.total_jobs(), 170);
        let a = shape.generate(&mut XorShift::new(99));
        let b = shape.generate(&mut XorShift::new(99));
        assert_eq!(a.len(), 170);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at_secs.to_bits(), y.at_secs.to_bits());
            assert_eq!(x.data_mb.to_bits(), y.data_mb.to_bits());
        }
        for w in a.windows(2) {
            assert!(w[0].at_secs < w[1].at_secs);
        }
        // a different seed moves the trace
        let c = shape.generate(&mut XorShift::new(100));
        assert!(a.iter().zip(&c).any(|(x, y)| x.at_secs != y.at_secs));
    }

    #[test]
    fn spike_and_concentrated_stages_compress_arrivals() {
        let slow = LoadShape::new(
            vec![LoadStage::soak(200, 30.0)],
            SizeDist::Menu(vec![150.0]),
            None,
        )
        .unwrap();
        let fast = LoadShape::new(
            vec![LoadStage::spike(200, 30.0, 4.0)],
            SizeDist::Menu(vec![150.0]),
            None,
        )
        .unwrap();
        let t_slow = slow.generate(&mut XorShift::new(1)).last().unwrap().at_secs;
        let t_fast = fast.generate(&mut XorShift::new(1)).last().unwrap().at_secs;
        // identical exponential draws, gap scaled exactly by the factor
        assert!((t_fast * 4.0 - t_slow).abs() < 1e-6, "{t_fast} vs {t_slow}");
        let burst = LoadShape::new(
            vec![LoadStage::concentrated(50, 10.0)],
            SizeDist::Menu(vec![150.0]),
            None,
        )
        .unwrap();
        let last = burst.generate(&mut XorShift::new(2)).last().unwrap().at_secs;
        // 50 arrivals with mean gap 0.2s: the burst lands in O(window)
        assert!(last < 50.0, "concentrated stage spread out to {last}s");
    }

    /// Truncated-Pareto sanity: the empirical tail matches
    /// `P(X > x) = (min/x)^alpha` below the cap, and the cap absorbs
    /// the rest of the mass.
    #[test]
    fn pareto_tail_index_survives_truncation() {
        let n = 20_000usize;
        let shape = LoadShape::new(
            vec![LoadStage::soak(n, 1.0)],
            SizeDist::Pareto { alpha: 1.2, min_mb: 100.0, cap_mb: 100_000.0 },
            None,
        )
        .unwrap();
        let sizes: Vec<f64> =
            shape.generate(&mut XorShift::new(2014)).iter().map(|a| a.data_mb).collect();
        assert!(sizes.iter().all(|&s| (100.0..=100_000.0).contains(&s)));
        let ccdf = |x: f64| sizes.iter().filter(|&&s| s > x).count() as f64 / n as f64;
        // CCDF at 2x and 8x the floor: 2^-1.2 ~ 0.435, 8^-1.2 ~ 0.0825
        assert!((ccdf(200.0) - 0.435).abs() < 0.02, "ccdf(2min) = {}", ccdf(200.0));
        assert!((ccdf(800.0) - 0.0825).abs() < 0.01, "ccdf(8min) = {}", ccdf(800.0));
        // a tight cap truncates: everything clamps into [min, cap] and
        // the atom at the cap carries the whole former tail
        let capped = LoadShape::new(
            vec![LoadStage::soak(n, 1.0)],
            SizeDist::Pareto { alpha: 1.2, min_mb: 100.0, cap_mb: 400.0 },
            None,
        )
        .unwrap();
        let cs: Vec<f64> =
            capped.generate(&mut XorShift::new(2014)).iter().map(|a| a.data_mb).collect();
        assert!(cs.iter().all(|&s| (100.0..=400.0).contains(&s)));
        let at_cap = cs.iter().filter(|&&s| s == 400.0).count() as f64 / n as f64;
        // P(raw >= 400) = 4^-1.2 ~ 0.19
        assert!((at_cap - 0.19).abs() < 0.02, "mass at cap = {at_cap}");
    }

    #[test]
    fn shape_constructors_reject_degenerate_inputs() {
        let menu = SizeDist::Menu(vec![150.0]);
        assert!(LoadShape::new(vec![], menu.clone(), None).is_err());
        assert!(LoadShape::new(vec![LoadStage::soak(0, 30.0)], menu.clone(), None).is_err());
        assert!(LoadShape::new(vec![LoadStage::soak(5, 0.0)], menu.clone(), None).is_err());
        assert!(LoadShape::new(vec![LoadStage::soak(5, -1.0)], menu.clone(), None).is_err());
        assert!(
            LoadShape::new(vec![LoadStage::ramp(5, 30.0, 0.0)], menu.clone(), None).is_err()
        );
        assert!(
            LoadShape::new(vec![LoadStage::spike(5, 30.0, 0.5)], menu.clone(), None).is_err()
        );
        assert!(
            LoadShape::new(vec![LoadStage::concentrated(5, -2.0)], menu.clone(), None).is_err()
        );
        assert!(LoadShape::new(vec![LoadStage::soak(5, 30.0)], SizeDist::Menu(vec![]), None)
            .is_err());
        assert!(LoadShape::new(
            vec![LoadStage::soak(5, 30.0)],
            SizeDist::Menu(vec![150.0, -1.0]),
            None
        )
        .is_err());
        for bad in [
            SizeDist::Pareto { alpha: 0.0, min_mb: 100.0, cap_mb: 1000.0 },
            SizeDist::Pareto { alpha: 1.5, min_mb: 0.0, cap_mb: 1000.0 },
            SizeDist::Pareto { alpha: 1.5, min_mb: 100.0, cap_mb: 50.0 },
        ] {
            assert!(LoadShape::new(vec![LoadStage::soak(5, 30.0)], bad, None).is_err());
        }
        for bad in [
            Diurnal { amplitude: 1.0, period_secs: 60.0 },
            Diurnal { amplitude: -0.1, period_secs: 60.0 },
            Diurnal { amplitude: 0.5, period_secs: 0.0 },
        ] {
            assert!(
                LoadShape::new(vec![LoadStage::soak(5, 30.0)], menu.clone(), Some(bad)).is_err()
            );
        }
    }

    #[test]
    #[should_panic(expected = "generate_poisson")]
    fn generate_poisson_rejects_non_positive_mean_gap() {
        let g = TraceGen { mean_interarrival_secs: 0.0, sizes_mb: vec![150.0] };
        g.generate_poisson(3, &mut XorShift::new(1));
    }

    #[test]
    #[should_panic(expected = "generate_poisson")]
    fn generate_poisson_rejects_an_empty_size_menu() {
        let g = TraceGen { mean_interarrival_secs: 60.0, sizes_mb: vec![] };
        g.generate_poisson(3, &mut XorShift::new(1));
    }
}
