//! Workload generation: the paper's Wordcount / Sort jobs, background
//! load, and synthetic job traces for the end-to-end driver.

pub mod background;
pub mod profiles;
pub mod tracegen;

pub use background::BackgroundLoad;
pub use profiles::{JobKind, WorkloadBuilder};
pub use tracegen::{Diurnal, JobArrival, LoadShape, LoadStage, SizeDist, StageShape, TraceGen};
