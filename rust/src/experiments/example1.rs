//! Example 1 / Example 2 / Discussion 1 / Fig. 3 / Fig. 4 driver.
//!
//! Runs all four schedulers on the paper's Fig. 2 testbed and *executes*
//! the assignments through the discrete-event engine, producing both the
//! scheduler-estimated and executed job completion times plus the Fig. 3
//! per-node timelines. Paper targets: HDS 39s, BAR 38s, BASS 35s,
//! Pre-BASS 34s. The cluster comes exclusively from the scenario layer
//! ([`ScenarioSpec::example1`]).

use crate::metrics::NodeTimeline;
use crate::runtime::CostModel;
use crate::scenario::{ScenarioSpec, SimSession};
use crate::util::Secs;

use super::fixtures::SchedulerKind;

/// Result of one scheduler's run on Example 1.
#[derive(Debug, Clone)]
pub struct Example1Outcome {
    pub scheduler: &'static str,
    /// Makespan the scheduler's own ledger predicts.
    pub estimated_jt: f64,
    /// Makespan after discrete-event execution (includes contention).
    pub executed_jt: f64,
    /// Fig. 3 Gantt data (task-node timelines).
    pub timelines: Vec<NodeTimeline>,
}

/// Run Example 1 (all four schedulers). `cost` selects the XLA artifact
/// or Rust fallback backend for BASS's batched evaluation.
pub fn run_example1(cost: &CostModel) -> Vec<Example1Outcome> {
    SchedulerKind::ALL.iter().map(|&k| run_one(k, cost)).collect()
}

/// Run a single scheduler on the Example 1 scenario.
pub fn run_one(kind: SchedulerKind, cost: &CostModel) -> Example1Outcome {
    let mut sess = SimSession::new(&ScenarioSpec::example1(kind));
    let tasks = sess.tasks.clone();
    let assignment = sess.schedule(&tasks, None, Secs::ZERO, cost);
    let estimated_jt = sess.estimated_makespan();

    // execute: engine node set = all 6 hosts; non-task hosts start free
    let records = sess.execute(&assignment);
    let executed_jt = records.iter().map(|r| r.finish.0).fold(0.0, f64::max);
    let timelines = NodeTimeline::build(&records, sess.nodes.len());
    Example1Outcome { scheduler: kind.label(), estimated_jt, executed_jt, timelines }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The headline reproduction: all four published makespans, both as
    /// scheduler estimates and after discrete-event execution.
    #[test]
    fn reproduces_fig4_exactly() {
        let cost = CostModel::rust_only();
        let out = run_example1(&cost);
        let jt: Vec<(&str, f64)> =
            out.iter().map(|o| (o.scheduler, o.executed_jt)).collect();
        assert_eq!(
            jt,
            vec![("HDS", 39.0), ("BAR", 38.0), ("BASS", 35.0), ("Pre-BASS", 34.0)]
        );
        // estimates match execution for the reservation-based schedulers
        for o in &out {
            if o.scheduler == "BASS" {
                assert_eq!(o.estimated_jt, o.executed_jt);
            }
        }
    }

    #[test]
    fn example2_node1_chain_finishes_at_32() {
        // Pre-BASS: ND1 runs TK1 (data prefetched by t=5) then two locals:
        // 5+9=14, 23, 32 — the paper's "reduced from 35 to 32".
        let cost = CostModel::rust_only();
        let o = run_one(SchedulerKind::PreBass, &cost);
        let nd1 = &o.timelines[0];
        let finishes: Vec<f64> = nd1.entries.iter().map(|e| e.finish).collect();
        assert_eq!(finishes, vec![14.0, 23.0, 32.0]);
        assert_eq!(o.executed_jt, 34.0); // TK8 on ND4 is now the last task
    }

    #[test]
    fn fig3a_bass_timelines() {
        let cost = CostModel::rust_only();
        let o = run_one(SchedulerKind::Bass, &cost);
        // ND1: TK1 (transfer 3->8, compute ->17), TK4 (->26), TK9 (->35)
        let nd1 = &o.timelines[0];
        let tasks: Vec<usize> = nd1.entries.iter().map(|e| e.task).collect();
        assert_eq!(tasks, vec![0, 3, 8]);
        assert_eq!(nd1.entries[0].compute_start, 8.0);
        assert_eq!(nd1.entries[2].finish, 35.0);
    }

    #[test]
    fn timelines_render_nonempty() {
        let cost = CostModel::rust_only();
        let o = run_one(SchedulerKind::Hds, &cost);
        let txt = NodeTimeline::render(&o.timelines, 1.0);
        assert!(txt.contains("ND1"));
        assert!(txt.contains("TK"));
    }
}
