//! Scalability study — the paper's stated future work ("we will evaluate
//! BASS's scalability in a much larger network cluster").
//!
//! Sweeps cluster size (nodes) with a proportionally sized map wave and
//! measures (a) the scheduler's decision latency and (b) the executed
//! makespan, BASS vs HDS. The XLA cost-model path amortizes with cluster
//! size (one batched evaluation per round regardless of n). Each sweep
//! point is a hermetic [`SimSession`], so the grid fans out across
//! `threads` workers; the *metrics* are bitwise-identical either way
//! (only the measured `sched_secs` wall times vary with load).

use std::time::Instant;

use crate::runtime::CostModel;
use crate::scenario::{
    parallel_map, BackgroundSpec, InitialLoad, ScenarioSpec, SimSession, TopologyShape,
    WorkloadSpec,
};
use crate::util::Secs;

use super::fixtures::SchedulerKind;

/// One scale sample.
#[derive(Debug, Clone)]
pub struct ScalePoint {
    pub nodes: usize,
    pub tasks: usize,
    pub scheduler: &'static str,
    /// Scheduling wall time (seconds).
    pub sched_secs: f64,
    /// Executed makespan (simulated seconds).
    pub makespan: f64,
}

/// The scenario one (hosts-per-switch, scheduler) point expands to: an
/// 8-switch tree in the shared-cluster regime (the paper's motivation) —
/// skewed initial load + background traffic making bandwidth scarce.
pub fn scale_spec(per_sw: usize, kind: SchedulerKind) -> ScenarioSpec {
    let n_nodes = 8 * per_sw;
    let mut s = ScenarioSpec::new(
        format!("scale-{n_nodes}nodes"),
        TopologyShape::Tree {
            switches: 8,
            hosts_per_switch: per_sw,
            edge_mbps: 100.0,
            uplink_mbps: 1000.0,
        },
        WorkloadSpec::MapWave { tasks: 2 * n_nodes, compute_secs: 20.0, output_mb: 16.0 },
    );
    s.scheduler = kind;
    s.replication = 2;
    s.seed = 31 + per_sw as u64;
    s.initial = InitialLoad::Sampled { max_secs: 60.0 };
    s.background = BackgroundSpec { flows: n_nodes / 4, rate_mb_s: 4.0 };
    s
}

/// The fat-tree variant: an 8-leaf, 4-spine fabric with `per_edge` hosts
/// per leaf — `per_edge = 128` is the thousand-node (1024-host, 2048-task)
/// grid the acceptance bar targets. Same shared-cluster regime as
/// [`scale_spec`].
pub fn fat_scale_spec(per_edge: usize, kind: SchedulerKind) -> ScenarioSpec {
    let n_nodes = 8 * per_edge;
    let mut s = ScenarioSpec::new(
        format!("scale-fat-{n_nodes}nodes"),
        TopologyShape::FatTree {
            edge_switches: 8,
            hosts_per_edge: per_edge,
            core_switches: 4,
            edge_mbps: 100.0,
            core_mbps: 10_000.0,
        },
        WorkloadSpec::MapWave { tasks: 2 * n_nodes, compute_secs: 20.0, output_mb: 16.0 },
    );
    s.scheduler = kind;
    s.replication = 2;
    s.seed = 57 + per_edge as u64;
    s.initial = InitialLoad::Sampled { max_secs: 60.0 };
    s.background = BackgroundSpec { flows: n_nodes / 4, rate_mb_s: 4.0 };
    s
}

/// Run one BASS-vs-HDS grid over prebuilt specs (shared by the tree and
/// fat-tree sweeps).
fn run_grid(specs: Vec<ScenarioSpec>, cost: &CostModel, threads: usize) -> Vec<ScalePoint> {
    parallel_map(specs, threads, |spec| {
        let label = spec.scheduler.label();
        let mut sess = SimSession::new(&spec);
        let tasks = sess.tasks.clone();
        let t0 = Instant::now();
        let a = sess.schedule(&tasks, None, Secs::ZERO, cost);
        let sched_secs = t0.elapsed().as_secs_f64();
        let records = sess.execute(&a);
        let makespan = records.iter().map(|r| r.finish.0).fold(0.0, f64::max);
        ScalePoint {
            nodes: sess.nodes.len(),
            tasks: tasks.len(),
            scheduler: label,
            sched_secs,
            makespan,
        }
    })
}

/// Run the sweep: `sizes` are hosts-per-switch counts on an 8-switch
/// tree; tasks = 2x nodes. `threads` fans points across workers.
pub fn run_scale(per_switch_sizes: &[usize], cost: &CostModel, threads: usize) -> Vec<ScalePoint> {
    let specs: Vec<ScenarioSpec> = per_switch_sizes
        .iter()
        .flat_map(|&per_sw| {
            [SchedulerKind::Bass, SchedulerKind::Hds]
                .into_iter()
                .map(move |k| scale_spec(per_sw, k))
        })
        .collect();
    run_grid(specs, cost, threads)
}

/// The thousand-node extension: `sizes` are hosts-per-leaf counts on the
/// 8-leaf fat tree (128 => 1024 nodes / 2048 tasks per point).
pub fn run_scale_fat(
    per_edge_sizes: &[usize],
    cost: &CostModel,
    threads: usize,
) -> Vec<ScalePoint> {
    run_scale_fat_with(
        per_edge_sizes,
        &[SchedulerKind::Bass, SchedulerKind::Hds],
        None,
        cost,
        threads,
    )
}

/// The fully parameterized fat-tree sweep: caller-chosen scheduler set
/// and an optional shard-count cap forwarded to every point's spec (the
/// `bass scale --fat --shards N` path). Sharding is schedule-invariant,
/// so `shards` changes wall times only.
pub fn run_scale_fat_with(
    per_edge_sizes: &[usize],
    kinds: &[SchedulerKind],
    shards: Option<usize>,
    cost: &CostModel,
    threads: usize,
) -> Vec<ScalePoint> {
    let specs: Vec<ScenarioSpec> = per_edge_sizes
        .iter()
        .flat_map(|&per_edge| {
            kinds.iter().map(move |&k| {
                let mut s = fat_scale_spec(per_edge, k);
                s.shards = shards;
                s
            })
        })
        .collect();
    run_grid(specs, cost, threads)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_sweep_shapes() {
        let pts = run_scale(&[2, 4], &CostModel::rust_only(), 1);
        assert_eq!(pts.len(), 4);
        for p in &pts {
            assert!(p.makespan > 0.0);
            assert!(p.sched_secs < 5.0, "scheduling too slow at {} nodes", p.nodes);
        }
        // Finding (recorded in EXPERIMENTS.md): at >=16 nodes with two
        // full waves of work, node-driven HDS edges out Algorithm 1's
        // task-order greedy by ~10% — the regime the paper never
        // evaluated (its clusters are 4-6 nodes). We assert BASS stays
        // within 25% rather than pretending it wins everywhere.
        for &n in &[16usize, 32] {
            let jt = |s: &str| {
                pts.iter().find(|p| p.scheduler == s && p.nodes == n).unwrap().makespan
            };
            assert!(jt("BASS") <= jt("HDS") * 1.25, "n={n}: BASS {} HDS {}", jt("BASS"), jt("HDS"));
        }
    }

    #[test]
    fn fat_tree_sweep_shapes() {
        let pts = run_scale_fat(&[2, 4], &CostModel::rust_only(), 1);
        assert_eq!(pts.len(), 4);
        for p in &pts {
            assert!(p.makespan > 0.0);
            assert_eq!(p.tasks, 2 * p.nodes);
        }
        assert_eq!(pts[0].nodes, 16);
        assert_eq!(pts[2].nodes, 32);
    }

    /// The acceptance bar: one BASS-vs-HDS point on the 8-leaf x 128-host
    /// fat tree (1024 nodes, 2048 tasks each) in under a minute. Ignored
    /// in the default test run (it is a perf gate, not a logic test):
    /// `cargo test --release -- --ignored fat_tree_kilonode`.
    #[test]
    #[ignore]
    fn fat_tree_kilonode_point_under_60s() {
        let t0 = std::time::Instant::now();
        let pts = run_scale_fat(&[128], &CostModel::rust_only(), 1);
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(pts.len(), 2);
        for p in &pts {
            assert_eq!(p.nodes, 1024);
            assert_eq!(p.tasks, 2048);
            assert!(p.makespan > 0.0);
            println!(
                "kilonode {}: sched {:.3}s, makespan {:.1}s",
                p.scheduler, p.sched_secs, p.makespan
            );
        }
        println!("kilonode wall: {wall:.2}s (budget 60s)");
        assert!(wall < 60.0, "BASS+HDS kilonode point took {wall:.1}s (budget 60s)");
    }

    /// The ten-kilonode companion gate: one point on the 8-leaf x
    /// 1280-host fat tree (10240 nodes, 20480 tasks) for all three
    /// schedulers, single-threaded so only one ten-kilohost session
    /// (topology, flows, ledgers, chunked cost blocks — each full input
    /// plane would be ~840MB unchunked) is live at a time. Exercises
    /// the whole sharded stack:
    /// hierarchical `PathCache` (a flat table would be ~7.5GB here),
    /// per-rack `ShardedIdleHeap`s and the chunked cost kernel.
    /// `cargo test --release -- --ignored fat_tree_10k`.
    #[test]
    #[ignore]
    fn fat_tree_10k_point_under_60s() {
        let kinds = [SchedulerKind::Bass, SchedulerKind::Hds, SchedulerKind::Bar];
        let t0 = std::time::Instant::now();
        let pts = run_scale_fat_with(&[1280], &kinds, None, &CostModel::rust_only(), 1);
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(pts.len(), 3);
        for p in &pts {
            assert_eq!(p.nodes, 10240);
            assert_eq!(p.tasks, 20480);
            assert!(p.makespan > 0.0);
            println!(
                "10k {}: sched {:.3}s, makespan {:.1}s",
                p.scheduler, p.sched_secs, p.makespan
            );
        }
        println!("10k wall: {wall:.2}s (budget 60s)");
        assert!(wall < 60.0, "BASS+HDS+BAR 10k point took {wall:.1}s (budget 60s)");
    }

    #[test]
    fn shard_cap_is_schedule_invariant() {
        // the acceptance pin at sweep granularity: capping the shard
        // count (all the way down to one flat shard) must not move a
        // single metric
        let cost = CostModel::rust_only();
        let kinds = [SchedulerKind::Bass, SchedulerKind::Hds, SchedulerKind::Bar];
        let default_plan = run_scale_fat_with(&[2, 4], &kinds, None, &cost, 1);
        for cap in [1usize, 3] {
            let capped = run_scale_fat_with(&[2, 4], &kinds, Some(cap), &cost, 1);
            assert_eq!(default_plan.len(), capped.len());
            for (a, b) in default_plan.iter().zip(&capped) {
                assert_eq!(a.nodes, b.nodes);
                assert_eq!(a.scheduler, b.scheduler);
                assert!(
                    a.makespan == b.makespan,
                    "{} n={} cap={}: {} != {}",
                    a.scheduler,
                    a.nodes,
                    cap,
                    a.makespan,
                    b.makespan
                );
            }
        }
    }

    #[test]
    fn threaded_sweep_metrics_are_bitwise_identical() {
        // acceptance: >= 4 sweep points, threads > 1 == serial, bitwise
        let cost = CostModel::rust_only();
        let serial = run_scale(&[1, 2, 3, 4], &cost, 1);
        let fanned = run_scale(&[1, 2, 3, 4], &cost, 4);
        assert_eq!(serial.len(), 8);
        assert_eq!(serial.len(), fanned.len());
        for (a, b) in serial.iter().zip(&fanned) {
            assert_eq!(a.nodes, b.nodes);
            assert_eq!(a.tasks, b.tasks);
            assert_eq!(a.scheduler, b.scheduler);
            assert!(
                a.makespan == b.makespan,
                "{} n={}: serial {} != fanned {}",
                a.scheduler,
                a.nodes,
                a.makespan,
                b.makespan
            );
        }
    }
}
