//! Scalability study — the paper's stated future work ("we will evaluate
//! BASS's scalability in a much larger network cluster").
//!
//! Sweeps cluster size (nodes) with a proportionally sized map wave and
//! measures (a) the scheduler's decision latency and (b) the executed
//! makespan, BASS vs HDS. The XLA cost-model path amortizes with cluster
//! size (one batched evaluation per round regardless of n).

use std::time::Instant;

use crate::cluster::Ledger;
use crate::hdfs::{Namenode, PlacementPolicy};
use crate::workload::BackgroundLoad;
use crate::mapreduce::TaskSpec;
use crate::runtime::CostModel;
use crate::sched::SchedCtx;
use crate::sim::{Engine, FlowNet};
use crate::topology::builders::tree_cluster;
use crate::util::{Secs, XorShift, BLOCK_MB};

use super::fixtures::SchedulerKind;

/// One scale sample.
#[derive(Debug, Clone)]
pub struct ScalePoint {
    pub nodes: usize,
    pub tasks: usize,
    pub scheduler: &'static str,
    /// Scheduling wall time (seconds).
    pub sched_secs: f64,
    /// Executed makespan (simulated seconds).
    pub makespan: f64,
}

/// Run the sweep: `sizes` are hosts-per-switch counts on an 8-switch
/// tree; tasks = 2x nodes.
pub fn run_scale(per_switch_sizes: &[usize], cost: &CostModel) -> Vec<ScalePoint> {
    let mut out = Vec::new();
    for &per_sw in per_switch_sizes {
        let n_sw = 8;
        let n_nodes = n_sw * per_sw;
        let m_tasks = 2 * n_nodes;
        for kind in [SchedulerKind::Bass, SchedulerKind::Hds] {
            let (topo, nodes) = tree_cluster(n_sw, per_sw, 100.0, 1000.0);
            let caps: Vec<f64> = topo.links.iter().map(|l| l.capacity_mbps).collect();
            let mut ctrl = crate::sdn::Controller::new(topo, 1.0);
            let mut net = FlowNet::new(&caps);
            let mut nn = Namenode::new();
            let mut rng = XorShift::new(31 + per_sw as u64);
            // shared-cluster regime (the paper's motivation): skewed
            // initial load + background traffic making bandwidth scarce
            let bg = BackgroundLoad::sample(&nodes, 60.0, n_nodes / 4, 4.0, &mut rng);
            bg.install(&mut ctrl, &mut net);
            let blocks = PlacementPolicy::RandomDistinct
                .place(&mut nn, &nodes, m_tasks, BLOCK_MB, 2, &mut rng);
            let tasks: Vec<TaskSpec> = blocks
                .iter()
                .enumerate()
                .map(|(i, &b)| TaskSpec::map(i, b, BLOCK_MB, Secs(20.0), 16.0))
                .collect();
            let init = bg.initial_idle.clone();
            let mut ledger = Ledger::with_initial(init.clone());
            let mut sched = kind.make();
            let t0 = Instant::now();
            let a = {
                let mut ctx = SchedCtx {
                    controller: &mut ctrl,
                    namenode: &nn,
                    ledger: &mut ledger,
                    authorized: nodes.clone(),
                    now: Secs::ZERO,
                    cost,
                    node_speed: Vec::new(),
                };
                sched.schedule(&tasks, None, &mut ctx)
            };
            let sched_secs = t0.elapsed().as_secs_f64();
            let mut engine = Engine::new(net, init);
            engine.load(&a);
            let records = engine.run();
            let makespan = records.iter().map(|r| r.finish.0).fold(0.0, f64::max);
            out.push(ScalePoint {
                nodes: n_nodes,
                tasks: m_tasks,
                scheduler: kind.label(),
                sched_secs,
                makespan,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_sweep_shapes() {
        let pts = run_scale(&[2, 4], &CostModel::rust_only());
        assert_eq!(pts.len(), 4);
        for p in &pts {
            assert!(p.makespan > 0.0);
            assert!(p.sched_secs < 5.0, "scheduling too slow at {} nodes", p.nodes);
        }
        // Finding (recorded in EXPERIMENTS.md): at >=16 nodes with two
        // full waves of work, node-driven HDS edges out Algorithm 1's
        // task-order greedy by ~10% — the regime the paper never
        // evaluated (its clusters are 4-6 nodes). We assert BASS stays
        // within 25% rather than pretending it wins everywhere.
        for &n in &[16usize, 32] {
            let jt = |s: &str| {
                pts.iter().find(|p| p.scheduler == s && p.nodes == n).unwrap().makespan
            };
            assert!(jt("BASS") <= jt("HDS") * 1.25, "n={n}: BASS {} HDS {}", jt("BASS"), jt("HDS"));
        }
    }
}
