//! Ablations over the design choices DESIGN.md calls out:
//!
//! * **Time-slot duration** (Section IV-A: "duration ... is a tunable
//!   parameter according to practical network scenarios") — how coarse
//!   can `TS` get before reservation quantization hurts BASS?
//! * **Background intensity** — BASS's edge over HDS should grow as
//!   bandwidth gets scarcer (the paper's core motivation).
//! * **Replication factor** — more replicas = more locality options; the
//!   bandwidth-aware tradeoff matters most at low replication.
//! * **Heterogeneous nodes** (Guo & Fox [14]) — per-node speed factors;
//!   BASS's Eq. 4 argmin includes per-node `TP`, HDS ignores it.

use crate::cluster::Ledger;
use crate::hdfs::Namenode;
use crate::mapreduce::TaskSpec;
use crate::runtime::CostModel;
use crate::sched::SchedCtx;
use crate::sim::{Engine, FlowNet};
use crate::topology::builders::tree_cluster;
use crate::util::{Secs, XorShift};
use crate::workload::{BackgroundLoad, JobKind, WorkloadBuilder};

use super::fixtures::SchedulerKind;
use super::table1::{run_cell, Table1Config};

/// One ablation sample.
#[derive(Debug, Clone)]
pub struct AblationPoint {
    pub x: f64,
    pub scheduler: &'static str,
    pub jt: f64,
}

/// Slot-duration sweep: JT of BASS at `slot_secs` ∈ `slots`.
pub fn ablate_slot_duration(slots: &[f64], cost: &CostModel) -> Vec<AblationPoint> {
    slots
        .iter()
        .flat_map(|&ts| {
            let mut cfg = Table1Config::paper(JobKind::Sort);
            cfg.slot_secs = ts;
            cfg.sizes_mb = vec![600.0];
            [SchedulerKind::Bass, SchedulerKind::Hds].into_iter().map(move |k| {
                let m = run_cell(&cfg, 600.0, k, cost);
                AblationPoint { x: ts, scheduler: k.label(), jt: m.jt }
            })
        })
        .collect()
}

/// Background-flow sweep: BASS-vs-HDS gap as contention grows.
pub fn ablate_background(flows: &[usize], cost: &CostModel) -> Vec<AblationPoint> {
    flows
        .iter()
        .flat_map(|&n| {
            let mut cfg = Table1Config::paper(JobKind::Sort);
            cfg.bg_flows = n;
            cfg.sizes_mb = vec![600.0];
            [SchedulerKind::Bass, SchedulerKind::Hds].into_iter().map(move |k| {
                let m = run_cell(&cfg, 600.0, k, cost);
                AblationPoint { x: n as f64, scheduler: k.label(), jt: m.jt }
            })
        })
        .collect()
}

/// Replication-factor sweep (1..=3 on the 6-node cluster).
pub fn ablate_replication(ks: &[usize], cost: &CostModel) -> Vec<AblationPoint> {
    ks.iter()
        .flat_map(|&k| {
            let mut cfg = Table1Config::paper(JobKind::Wordcount);
            cfg.replication = k;
            cfg.sizes_mb = vec![600.0];
            [SchedulerKind::Bass, SchedulerKind::Hds].into_iter().map(move |s| {
                let m = run_cell(&cfg, 600.0, s, cost);
                AblationPoint { x: k as f64, scheduler: s.label(), jt: m.jt }
            })
        })
        .collect()
}

/// Heterogeneous cluster: half the nodes are `slow_factor`x slower.
/// Returns (scheduler, executed JT) for one 16-map wave.
pub fn ablate_heterogeneity(slow_factor: f64, cost: &CostModel) -> Vec<(&'static str, f64)> {
    [SchedulerKind::Bass, SchedulerKind::Hds]
        .into_iter()
        .map(|kind| {
            let (topo, nodes) = tree_cluster(2, 3, 100.0, 100.0);
            let caps: Vec<f64> = topo.links.iter().map(|l| l.capacity_mbps).collect();
            let mut ctrl = crate::sdn::Controller::new(topo, 1.0);
            let mut net = FlowNet::new(&caps);
            let mut rng = XorShift::new(99);
            let bg = BackgroundLoad::sample(&nodes, 10.0, 2, 3.0, &mut rng);
            bg.install(&mut ctrl, &mut net);
            let mut nn = Namenode::new();
            let job = WorkloadBuilder::new(JobKind::Wordcount)
                .build(0, 1024.0, &nodes, &mut nn, &mut rng);
            let maps: Vec<TaskSpec> = job.maps().cloned().collect();
            // nodes 0..3 fast, 3..6 slow
            let speed: Vec<f64> =
                (0..nodes.len()).map(|i| if i < 3 { 1.0 } else { slow_factor }).collect();
            let init: Vec<Secs> = bg.initial_idle.clone();
            let mut ledger = Ledger::with_initial(init.clone());
            let mut sched = kind.make();
            let a = {
                let mut ctx = SchedCtx {
                    controller: &mut ctrl,
                    namenode: &nn,
                    ledger: &mut ledger,
                    authorized: nodes.clone(),
                    now: Secs::ZERO,
                    cost,
                    node_speed: speed,
                };
                sched.schedule(&maps, None, &mut ctx)
            };
            let mut engine = Engine::new(net, init);
            engine.load(&a);
            let records = engine.run();
            let jt = records.iter().map(|r| r.finish.0).fold(0.0, f64::max);
            (kind.label(), jt)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slot_duration_monotone_cost_for_bass() {
        // coarser slots can only round reservations up
        let pts = ablate_slot_duration(&[0.5, 4.0], &CostModel::rust_only());
        let bass_fine = pts.iter().find(|p| p.scheduler == "BASS" && p.x == 0.5).unwrap().jt;
        let bass_coarse =
            pts.iter().find(|p| p.scheduler == "BASS" && p.x == 4.0).unwrap().jt;
        assert!(bass_coarse + 1e-9 >= bass_fine, "{bass_coarse} vs {bass_fine}");
        // HDS ignores slots entirely
        let hds: Vec<f64> =
            pts.iter().filter(|p| p.scheduler == "HDS").map(|p| p.jt).collect();
        assert!((hds[0] - hds[1]).abs() < 1e-9);
    }

    #[test]
    fn background_widens_the_gap() {
        let pts = ablate_background(&[0, 6], &CostModel::rust_only());
        let gap = |n: f64| {
            let h = pts.iter().find(|p| p.scheduler == "HDS" && p.x == n).unwrap().jt;
            let b = pts.iter().find(|p| p.scheduler == "BASS" && p.x == n).unwrap().jt;
            h - b
        };
        assert!(gap(6.0) >= gap(0.0) - 2.0, "gap(6)={} gap(0)={}", gap(6.0), gap(0.0));
    }

    #[test]
    fn heterogeneity_bass_beats_hds() {
        // with 3x-slow nodes, the Eq.4 argmin (TP included) must not lose
        // to locality-greedy HDS
        let out = ablate_heterogeneity(3.0, &CostModel::rust_only());
        let jt = |n: &str| out.iter().find(|(s, _)| *s == n).unwrap().1;
        assert!(jt("BASS") <= jt("HDS") + 1e-9, "BASS {} HDS {}", jt("BASS"), jt("HDS"));
    }

    #[test]
    fn replication_sweep_runs() {
        let pts = ablate_replication(&[1, 3], &CostModel::rust_only());
        assert_eq!(pts.len(), 4);
        assert!(pts.iter().all(|p| p.jt > 0.0));
    }
}
