//! Ablations over the design choices DESIGN.md calls out:
//!
//! * **Time-slot duration** (Section IV-A: "duration ... is a tunable
//!   parameter according to practical network scenarios") — how coarse
//!   can `TS` get before reservation quantization hurts BASS?
//! * **Background intensity** — BASS's edge over HDS should grow as
//!   bandwidth gets scarcer (the paper's core motivation).
//! * **Replication factor** — more replicas = more locality options; the
//!   bandwidth-aware tradeoff matters most at low replication.
//! * **Heterogeneous nodes** (Guo & Fox [14]) — per-node speed factors;
//!   BASS's Eq. 4 argmin includes per-node `TP`, HDS ignores it.
//!
//! Every ablation point is a [`SimSession`] built from a tweaked
//! [`ScenarioSpec`]; no driver wires substrates by hand.

use crate::runtime::CostModel;
use crate::scenario::{
    BackgroundSpec, InitialLoad, ScenarioSpec, SimSession, TopologyShape, WorkloadSpec,
};
use crate::util::Secs;
use crate::workload::JobKind;

use super::fixtures::SchedulerKind;
use super::table1::{run_cell, Table1Config};

/// One ablation sample.
#[derive(Debug, Clone)]
pub struct AblationPoint {
    pub x: f64,
    pub scheduler: &'static str,
    pub jt: f64,
}

/// Slot-duration sweep: JT of BASS at `slot_secs` ∈ `slots`.
pub fn ablate_slot_duration(slots: &[f64], cost: &CostModel) -> Vec<AblationPoint> {
    slots
        .iter()
        .flat_map(|&ts| {
            let mut cfg = Table1Config::paper(JobKind::Sort);
            cfg.slot_secs = ts;
            cfg.sizes_mb = vec![600.0];
            [SchedulerKind::Bass, SchedulerKind::Hds].into_iter().map(move |k| {
                let m = run_cell(&cfg, 600.0, k, cost);
                AblationPoint { x: ts, scheduler: k.label(), jt: m.jt }
            })
        })
        .collect()
}

/// Background-flow sweep: BASS-vs-HDS gap as contention grows.
pub fn ablate_background(flows: &[usize], cost: &CostModel) -> Vec<AblationPoint> {
    flows
        .iter()
        .flat_map(|&n| {
            let mut cfg = Table1Config::paper(JobKind::Sort);
            cfg.bg_flows = n;
            cfg.sizes_mb = vec![600.0];
            [SchedulerKind::Bass, SchedulerKind::Hds].into_iter().map(move |k| {
                let m = run_cell(&cfg, 600.0, k, cost);
                AblationPoint { x: n as f64, scheduler: k.label(), jt: m.jt }
            })
        })
        .collect()
}

/// Replication-factor sweep (1..=3 on the 6-node cluster).
pub fn ablate_replication(ks: &[usize], cost: &CostModel) -> Vec<AblationPoint> {
    ks.iter()
        .flat_map(|&k| {
            let mut cfg = Table1Config::paper(JobKind::Wordcount);
            cfg.replication = k;
            cfg.sizes_mb = vec![600.0];
            [SchedulerKind::Bass, SchedulerKind::Hds].into_iter().map(move |s| {
                let m = run_cell(&cfg, 600.0, s, cost);
                AblationPoint { x: k as f64, scheduler: s.label(), jt: m.jt }
            })
        })
        .collect()
}

/// The heterogeneous-cluster scenario: 2x3 tree, half the nodes
/// `slow_factor`x slower, one 16-map Wordcount wave.
pub fn hetero_spec(slow_factor: f64, kind: SchedulerKind) -> ScenarioSpec {
    let mut s = ScenarioSpec::new(
        format!("hetero-{slow_factor}x"),
        TopologyShape::Tree {
            switches: 2,
            hosts_per_switch: 3,
            edge_mbps: 100.0,
            uplink_mbps: 100.0,
        },
        WorkloadSpec::Job { kind: JobKind::Wordcount, data_mb: 1024.0 },
    );
    s.scheduler = kind;
    s.seed = 99;
    s.initial = InitialLoad::Sampled { max_secs: 10.0 };
    s.background = BackgroundSpec { flows: 2, rate_mb_s: 3.0 };
    // nodes 0..3 fast, 3..6 slow
    s.node_speed = (0..6).map(|i| if i < 3 { 1.0 } else { slow_factor }).collect();
    s
}

/// Heterogeneous cluster: half the nodes are `slow_factor`x slower.
/// Returns (scheduler, executed JT) for one 16-map wave.
pub fn ablate_heterogeneity(slow_factor: f64, cost: &CostModel) -> Vec<(&'static str, f64)> {
    [SchedulerKind::Bass, SchedulerKind::Hds]
        .into_iter()
        .map(|kind| {
            let mut sess = SimSession::new(&hetero_spec(slow_factor, kind));
            let maps: Vec<_> =
                sess.job.clone().expect("hetero job").maps().cloned().collect();
            let a = sess.schedule(&maps, None, Secs::ZERO, cost);
            let records = sess.execute(&a);
            let jt = records.iter().map(|r| r.finish.0).fold(0.0, f64::max);
            (kind.label(), jt)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slot_duration_monotone_cost_for_bass() {
        // coarser slots can only round reservations up
        let pts = ablate_slot_duration(&[0.5, 4.0], &CostModel::rust_only());
        let bass_fine = pts.iter().find(|p| p.scheduler == "BASS" && p.x == 0.5).unwrap().jt;
        let bass_coarse =
            pts.iter().find(|p| p.scheduler == "BASS" && p.x == 4.0).unwrap().jt;
        assert!(bass_coarse + 1e-9 >= bass_fine, "{bass_coarse} vs {bass_fine}");
        // HDS ignores slots entirely
        let hds: Vec<f64> =
            pts.iter().filter(|p| p.scheduler == "HDS").map(|p| p.jt).collect();
        assert!((hds[0] - hds[1]).abs() < 1e-9);
    }

    #[test]
    fn background_widens_the_gap() {
        let pts = ablate_background(&[0, 6], &CostModel::rust_only());
        let gap = |n: f64| {
            let h = pts.iter().find(|p| p.scheduler == "HDS" && p.x == n).unwrap().jt;
            let b = pts.iter().find(|p| p.scheduler == "BASS" && p.x == n).unwrap().jt;
            h - b
        };
        assert!(gap(6.0) >= gap(0.0) - 2.0, "gap(6)={} gap(0)={}", gap(6.0), gap(0.0));
    }

    #[test]
    fn heterogeneity_bass_beats_hds() {
        // with 3x-slow nodes, the Eq.4 argmin (TP included) must not lose
        // to locality-greedy HDS
        let out = ablate_heterogeneity(3.0, &CostModel::rust_only());
        let jt = |n: &str| out.iter().find(|(s, _)| *s == n).unwrap().1;
        assert!(jt("BASS") <= jt("HDS") + 1e-9, "BASS {} HDS {}", jt("BASS"), jt("HDS"));
    }

    #[test]
    fn replication_sweep_runs() {
        let pts = ablate_replication(&[1, 3], &CostModel::rust_only());
        assert_eq!(pts.len(), 4);
        assert!(pts.iter().all(|p| p.jt > 0.0));
    }
}
