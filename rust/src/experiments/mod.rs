//! One driver per paper table/figure — shared by `examples/`, `benches/`
//! and the CLI. See DESIGN.md's experiment index.
//!
//! Drivers are thin: each expands its knobs into [`crate::scenario`]
//! specs and runs the resulting sessions; none of them wires
//! `Controller`/`Namenode`/`Ledger`/`FlowNet` by hand.

pub mod ablations;
pub mod dynamics;
pub mod estimate;
pub mod example1;
pub mod example3;
pub mod fairness;
pub mod fig5;
pub mod fixtures;
pub mod scale;
pub mod skew;
pub mod soak;
pub mod stream;
pub mod table1;

pub use ablations::{
    ablate_background, ablate_heterogeneity, ablate_replication, ablate_slot_duration,
    hetero_spec, AblationPoint,
};
pub use dynamics::{churn_spec, run_dynamics, ChurnPoint};
pub use estimate::{estimate_spec, run_estimate, EstimatePoint};
pub use example1::{run_example1, run_one, Example1Outcome};
pub use example3::{example3_spec, run_example3, Example3Outcome};
pub use fairness::{
    fairness_tenancy, run_fairness_sweep, run_fairness_sweep_with, FairnessPoint,
};
pub use fig5::run_fig5;
pub use fixtures::{example1_fixture, makespan, Example1Fixture, SchedulerKind};
pub use scale::{
    fat_scale_spec, run_scale, run_scale_fat, run_scale_fat_with, scale_spec, ScalePoint,
};
pub use skew::{run_skew, skew_policies, skew_spec, SkewPoint};
pub use soak::{run_soak_sweep_with, SoakPoint};
pub use stream::{
    run_stream_sweep, run_stream_sweep_with, stream_cluster, stream_spec, StreamPoint,
};
pub use table1::{run_cell, run_cell_for_bench, run_table1, Table1Config, Table1Row};
