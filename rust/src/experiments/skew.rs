//! Replication/skew sweep: how much the bandwidth-aware replica choice
//! buys as layouts get more (or less) redundant and more skewed.
//!
//! The paper evaluates hand-placed single-source layouts; this family
//! sweeps the **data layer** instead: replication factor x placement
//! policy (Hadoop-random, rack-aware, hotspot-skewed) on a 16-node
//! two-rack-deep tree with contended uplinks and background traffic.
//! Each cell runs the same map wave for HDS, BAR, BASS **and BASS under
//! the legacy idle-only source rule** (`bw_aware_sources = false`) — the
//! BASS vs BASS-idle column is the direct measurement of the replica-
//! selection fix, and it can only appear at replication >= 2 (with one
//! replica the rules provably coincide; see `rust/tests/proptests.rs`).
//! All schedulers at one (replication, placement) cell share the seed,
//! so every delta is scheduling policy. See EXPERIMENTS.md.

use crate::runtime::CostModel;
use crate::scenario::{
    parallel_map, BackgroundSpec, InitialLoad, ScenarioSpec, SimSession, TopologyShape,
    WorkloadSpec,
};
use crate::hdfs::PlacementPolicy;
use crate::util::Secs;

use super::fixtures::SchedulerKind;

/// One executed (replication, placement, scheduler) sweep point.
#[derive(Debug, Clone)]
pub struct SkewPoint {
    pub replication: usize,
    pub placement: &'static str,
    /// Scheduler label; `BASS-idle` is BASS under the legacy source rule.
    pub scheduler: &'static str,
    pub makespan: f64,
    pub locality: f64,
    /// Placements that committed a remote pull (carry a source).
    pub remote_pulls: usize,
}

/// The placement policies the sweep walks.
pub fn skew_policies() -> Vec<PlacementPolicy> {
    vec![
        PlacementPolicy::RandomDistinct,
        PlacementPolicy::RackAware,
        PlacementPolicy::Hotspot { hot: 3, bias: 0.85 },
    ]
}

/// The scenario one (replication, placement, scheduler, rule) cell
/// expands to: a 16-node / 4-rack tree with tight uplinks and permanent
/// background flows — the regime where holders differ in path bandwidth.
pub fn skew_spec(
    replication: usize,
    placement: PlacementPolicy,
    kind: SchedulerKind,
    bw_aware: bool,
) -> ScenarioSpec {
    let mut s = ScenarioSpec::new(
        format!("skew-r{replication}-{}", placement.label()),
        TopologyShape::Tree {
            switches: 4,
            hosts_per_switch: 4,
            edge_mbps: 100.0,
            uplink_mbps: 200.0,
        },
        WorkloadSpec::MapWave { tasks: 32, compute_secs: 10.0, output_mb: 0.0 },
    );
    s.scheduler = kind;
    s.placement = placement;
    s.replication = replication;
    s.bw_aware_sources = bw_aware;
    s.seed = 777;
    s.initial = InitialLoad::Sampled { max_secs: 12.0 };
    s.background = BackgroundSpec { flows: 6, rate_mb_s: 4.0 };
    s
}

/// The sweep testbed's node count (4 switches x 4 hosts) — replication
/// factors beyond it would be silently clamped by the session, printing
/// fabricated duplicate rows, so [`run_skew`] rejects them up front.
pub const SKEW_NODES: usize = 16;

/// Run the sweep over `reps x policies x {HDS, BAR, BASS, BASS-idle}`,
/// fanned across `threads` workers (bitwise-identical to serial).
pub fn run_skew(reps: &[usize], cost: &CostModel, threads: usize) -> Vec<SkewPoint> {
    assert!(
        reps.iter().all(|&r| (1..=SKEW_NODES).contains(&r)),
        "replication factors must be in [1, {SKEW_NODES}] (the sweep's cluster size), got {reps:?}"
    );
    let points: Vec<(usize, PlacementPolicy, SchedulerKind, bool)> = reps
        .iter()
        .flat_map(|&r| {
            skew_policies().into_iter().flat_map(move |p| {
                [
                    (r, p.clone(), SchedulerKind::Hds, true),
                    (r, p.clone(), SchedulerKind::Bar, true),
                    (r, p.clone(), SchedulerKind::Bass, true),
                    (r, p, SchedulerKind::Bass, false),
                ]
            })
        })
        .collect();
    parallel_map(points, threads, |(r, p, kind, bw_aware)| {
        let label = match (kind, bw_aware) {
            (SchedulerKind::Bass, false) => "BASS-idle",
            _ => kind.label(),
        };
        let placement = p.label();
        let mut sess = SimSession::new(&skew_spec(r, p, kind, bw_aware));
        let tasks = sess.tasks.clone();
        let a = sess.schedule(&tasks, None, Secs::ZERO, cost);
        let locality = a.locality_ratio();
        let remote_pulls = a.placements.iter().filter(|pl| pl.source.is_some()).count();
        let records = sess.execute(&a);
        let makespan = records.iter().map(|rec| rec.finish.0).fold(0.0, f64::max);
        SkewPoint { replication: r, placement, scheduler: label, makespan, locality, remote_pulls }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reps() -> Vec<usize> {
        match std::env::var("BASS_BENCH_QUICK") {
            Ok(_) => vec![2],
            Err(_) => vec![1, 2],
        }
    }

    #[test]
    fn sweep_covers_the_grid_and_completes() {
        let rs = reps();
        let pts = run_skew(&rs, &CostModel::rust_only(), 1);
        assert_eq!(pts.len(), rs.len() * 3 * 4);
        for p in &pts {
            assert!(p.makespan > 0.0, "{} r{}", p.scheduler, p.replication);
            assert!((0.0..=1.0).contains(&p.locality));
        }
    }

    #[test]
    fn single_replica_rules_coincide() {
        // at replication 1 BASS and BASS-idle must agree exactly
        let pts = run_skew(&[1], &CostModel::rust_only(), 2);
        for policy in ["random", "rack_aware", "hotspot"] {
            let ms = |s: &str| {
                pts.iter()
                    .find(|p| p.scheduler == s && p.placement == policy)
                    .unwrap()
                    .makespan
            };
            assert_eq!(ms("BASS"), ms("BASS-idle"), "{policy}");
        }
    }

    #[test]
    fn sweep_is_deterministic_and_thread_invariant() {
        let cost = CostModel::rust_only();
        let serial = run_skew(&[2], &cost, 1);
        let fanned = run_skew(&[2], &cost, 4);
        assert_eq!(serial.len(), fanned.len());
        for (a, b) in serial.iter().zip(&fanned) {
            assert_eq!(a.scheduler, b.scheduler);
            assert_eq!(a.placement, b.placement);
            assert_eq!(a.makespan, b.makespan);
            assert_eq!(a.remote_pulls, b.remote_pulls);
        }
    }
}
