//! Shared experiment fixtures — most importantly the paper's Example 1.

use crate::cluster::Ledger;
use crate::hdfs::Namenode;
use crate::mapreduce::TaskSpec;
use crate::sched::{Bar, Bass, Hds, PreBass, Scheduler};
use crate::sdn::Controller;
use crate::topology::builders::fig2;
use crate::topology::NodeId;
use crate::util::Secs;

/// Selector for the paper's four schedulers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerKind {
    Hds,
    Bar,
    Bass,
    PreBass,
}

impl SchedulerKind {
    pub const ALL: [SchedulerKind; 4] =
        [SchedulerKind::Hds, SchedulerKind::Bar, SchedulerKind::Bass, SchedulerKind::PreBass];

    pub fn label(&self) -> &'static str {
        match self {
            SchedulerKind::Hds => "HDS",
            SchedulerKind::Bar => "BAR",
            SchedulerKind::Bass => "BASS",
            SchedulerKind::PreBass => "Pre-BASS",
        }
    }

    pub fn make(&self) -> Box<dyn Scheduler> {
        match self {
            SchedulerKind::Hds => Box::new(Hds::new()),
            SchedulerKind::Bar => Box::new(Bar::new()),
            SchedulerKind::Bass => Box::new(Bass::new()),
            SchedulerKind::PreBass => Box::new(PreBass::new()),
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "hds" => Some(SchedulerKind::Hds),
            "bar" => Some(SchedulerKind::Bar),
            "bass" => Some(SchedulerKind::Bass),
            "pre-bass" | "prebass" | "pre_bass" => Some(SchedulerKind::PreBass),
            _ => None,
        }
    }
}

/// The Example 1 testbed: Fig. 2 topology at the paper's effective
/// 12.8 MB/s (the paper rounds 64MB/100Mbps to 5s), 9 map tasks with
/// 2 replicas each, TP = 9s, initial loads `ΥI = [3, 9, 20, 7]`.
///
/// The replica placement is reverse-engineered from the paper's Figs.
/// 3(a)-(d) (only TK1's `{ND2, ND3}` is given explicitly) such that the
/// node-driven HDS trace, BAR's second phase, and Algorithm 1 all land
/// exactly on the published timelines: HDS 39s, BAR 38s, BASS 35s,
/// Pre-BASS 34s. See DESIGN.md.
pub struct Example1Fixture {
    pub ctrl: Controller,
    pub nn: Namenode,
    pub ledger: Ledger,
    pub nodes: Vec<NodeId>,
    pub tasks: Vec<TaskSpec>,
    /// Initial idle times per task node (for engine seeding).
    pub initial_idle: Vec<Secs>,
    /// All link capacities in Mbps (for FlowNet construction).
    pub link_caps_mbps: Vec<f64>,
}

/// Build the Example 1 fixture.
pub fn example1_fixture() -> Example1Fixture {
    let f = fig2(102.4);
    let link_caps_mbps = (0..f.topo.n_links()).map(|_| 102.4).collect();
    let ctrl = Controller::new(f.topo, 1.0);
    let nd = f.task_nodes;
    let mut nn = Namenode::new();
    let reps: [[usize; 2]; 9] = [
        [1, 2], // TK1 {ND2, ND3} — given in the paper
        [0, 3], // TK2 {ND1, ND4}
        [0, 1], // TK3 {ND1, ND2}
        [2, 0], // TK4 {ND3, ND1}
        [3, 1], // TK5 {ND4, ND2}
        [1, 2], // TK6 {ND2, ND3}
        [0, 2], // TK7 {ND1, ND3}
        [3, 0], // TK8 {ND4, ND1}
        [2, 0], // TK9 {ND3, ND1}
    ];
    let mut tasks = Vec::new();
    for (i, r) in reps.iter().enumerate() {
        let b = nn.add_block(64.0, vec![nd[r[0]], nd[r[1]]]);
        tasks.push(TaskSpec::map(i, b, 64.0, Secs(9.0), 0.0));
    }
    let initial_idle = vec![Secs(3.0), Secs(9.0), Secs(20.0), Secs(7.0)];
    let ledger = Ledger::with_initial(vec![
        Secs(3.0),
        Secs(9.0),
        Secs(20.0),
        Secs(7.0),
        Secs::INF,
        Secs::INF,
    ]);
    Example1Fixture { ctrl, nn, ledger, nodes: nd.to_vec(), tasks, initial_idle, link_caps_mbps }
}

/// Makespan over the task nodes of a ledger.
pub fn makespan(ledger: &Ledger, nodes: &[NodeId]) -> f64 {
    nodes.iter().map(|&n| ledger.idle(n).0).fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixture_shape() {
        let f = example1_fixture();
        assert_eq!(f.tasks.len(), 9);
        assert_eq!(f.nodes.len(), 4);
        assert_eq!(f.link_caps_mbps.len(), 8);
        // TK1 replicas are the paper's {ND2, ND3}
        let b = f.tasks[0].input.unwrap();
        assert_eq!(f.nn.block(b).replicas, vec![f.nodes[1], f.nodes[2]]);
    }

    #[test]
    fn scheduler_kind_parse_roundtrip() {
        for k in SchedulerKind::ALL {
            assert_eq!(SchedulerKind::parse(k.label()), Some(k));
        }
        assert_eq!(SchedulerKind::parse("nope"), None);
    }
}
