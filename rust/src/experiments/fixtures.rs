//! Shared experiment fixtures — most importantly the paper's Example 1.
//!
//! The scheduler registry now lives in [`crate::sched::kind`] and the
//! cluster wiring in [`crate::scenario`]; this module re-exports the
//! registry for compatibility and decomposes an Example 1 session into
//! the flat fixture the scheduler unit tests poke at.

use crate::cluster::Ledger;
use crate::hdfs::Namenode;
use crate::mapreduce::TaskSpec;
use crate::scenario::{ScenarioSpec, SimSession};
use crate::sdn::Controller;
use crate::topology::NodeId;
use crate::util::Secs;

/// Re-export: selector for the paper's four schedulers (promoted to
/// `sched::kind`; kept here so existing imports stay valid).
pub use crate::sched::SchedulerKind;

/// The Example 1 testbed: Fig. 2 topology at the paper's effective
/// 12.8 MB/s (the paper rounds 64MB/100Mbps to 5s), 9 map tasks with
/// 2 replicas each, TP = 9s, initial loads `ΥI = [3, 9, 20, 7]`.
///
/// The replica placement is reverse-engineered from the paper's Figs.
/// 3(a)-(d) (only TK1's `{ND2, ND3}` is given explicitly) such that the
/// node-driven HDS trace, BAR's second phase, and Algorithm 1 all land
/// exactly on the published timelines: HDS 39s, BAR 38s, BASS 35s,
/// Pre-BASS 34s. See DESIGN.md.
pub struct Example1Fixture {
    pub ctrl: Controller,
    pub nn: Namenode,
    pub ledger: Ledger,
    pub nodes: Vec<NodeId>,
    pub tasks: Vec<TaskSpec>,
    /// Initial idle times per task node (for engine seeding).
    pub initial_idle: Vec<Secs>,
    /// All link capacities in Mbps (for FlowNet construction).
    pub link_caps_mbps: Vec<f64>,
}

/// Build the Example 1 fixture (decomposed from a [`SimSession`] so the
/// scheduler unit tests can hold each substrate piece separately).
pub fn example1_fixture() -> Example1Fixture {
    let SimSession { ctrl, nn, ledger, nodes, tasks, initial_idle, link_caps_mbps, .. } =
        SimSession::new(&ScenarioSpec::example1(SchedulerKind::Bass));
    Example1Fixture { ctrl, nn, ledger, nodes, tasks, initial_idle, link_caps_mbps }
}

/// Makespan over the task nodes of a ledger.
pub fn makespan(ledger: &Ledger, nodes: &[NodeId]) -> f64 {
    nodes.iter().map(|&n| ledger.idle(n).0).fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixture_shape() {
        let f = example1_fixture();
        assert_eq!(f.tasks.len(), 9);
        assert_eq!(f.nodes.len(), 4);
        assert_eq!(f.link_caps_mbps.len(), 8);
        assert_eq!(f.initial_idle, vec![Secs(3.0), Secs(9.0), Secs(20.0), Secs(7.0)]);
        // TK1 replicas are the paper's {ND2, ND3}
        let b = f.tasks[0].input.unwrap();
        assert_eq!(f.nn.block(b).replicas, vec![f.nodes[1], f.nodes[2]]);
    }

    #[test]
    fn scheduler_kind_parse_roundtrip() {
        for k in SchedulerKind::ALL {
            assert_eq!(SchedulerKind::parse(k.label()), Some(k));
        }
        assert_eq!(SchedulerKind::parse("nope"), None);
    }
}
