//! Example 3 driver: OpenFlow QoS queues for shuffle traffic.
//!
//! The paper caps both switches at 150 Mbps and configures Q1 = 100 Mbps
//! (shuffle), Q2 = 40 Mbps (other Hadoop), Q3 = 10 Mbps (background),
//! versus the default single shared 150 Mbps queue. With background
//! traffic present, the queued scheme finishes the shuffle markedly
//! earlier because the shuffle no longer splits the pipe with background
//! flows.
//!
//! The Fig. 2 cluster (and its QoS-configured flow network) comes from
//! the scenario layer; this driver only injects the flows.

use crate::scenario::{ScenarioSpec, SimSession, TopologyShape, WorkloadSpec};
use crate::sdn::{QosPolicy, TrafficClass};

/// Outcome of the QoS comparison.
#[derive(Debug, Clone)]
pub struct Example3Outcome {
    /// Shuffle completion time with one shared 150 Mbps queue.
    pub shared_secs: f64,
    /// Shuffle completion time with the paper's Q1/Q2/Q3 scheme.
    pub queued_secs: f64,
    /// queued vs shared speedup factor.
    pub speedup: f64,
}

/// The Example 3 scenario: Fig. 2 at the example's 150 Mbps switch rate,
/// optionally with the paper's queue policy installed.
pub fn example3_spec(qos: Option<QosPolicy>) -> ScenarioSpec {
    let mut s = ScenarioSpec::new(
        "example3",
        TopologyShape::Fig2 { link_mbps: 150.0 },
        WorkloadSpec::None,
    );
    s.qos = qos;
    s
}

/// Run the comparison: a 640 MB shuffle from ND2 to ND3 (crosses both
/// switches) against `n_background` permanent background flows on the
/// same path, plus one "other Hadoop" flow.
pub fn run_example3(n_background: usize) -> Example3Outcome {
    let shared = run_mode(None, n_background);
    let queued = run_mode(Some(QosPolicy::example3()), n_background);
    Example3Outcome {
        shared_secs: shared,
        queued_secs: queued,
        speedup: shared / queued.max(1e-9),
    }
}

fn run_mode(qos: Option<QosPolicy>, n_background: usize) -> f64 {
    let sess = SimSession::new(&example3_spec(qos));
    let shuffle_path = sess.route(sess.nodes[1], sess.nodes[2]).unwrap();
    let other_path = sess.route(sess.nodes[0], sess.nodes[3]).unwrap();
    let mut net = sess.net;
    for _ in 0..n_background {
        net.add_background(shuffle_path.clone(), TrafficClass::Background);
    }
    net.add_background(other_path, TrafficClass::HadoopOther);
    let shuffle = net.add_flow(shuffle_path, 640.0, TrafficClass::Shuffle);
    // drain until the shuffle finishes
    let mut guard = 0;
    loop {
        let (t, id) = net.next_completion().expect("shuffle must finish");
        net.settle(t);
        if id == shuffle {
            return t.0;
        }
        net.remove_flow(id);
        guard += 1;
        assert!(guard < 10_000, "runaway drain");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queued_beats_shared_with_background() {
        let o = run_example3(5);
        assert!(
            o.queued_secs < o.shared_secs,
            "QoS should win: queued={} shared={}",
            o.queued_secs,
            o.shared_secs
        );
        // shared splits 150 among 7 flows (~21.4 Mbps for the shuffle);
        // queued gives the shuffle Q1's full 100 Mbps => >3x speedup
        assert!(o.speedup > 3.0, "speedup {}", o.speedup);
    }

    #[test]
    fn no_background_means_small_gap() {
        // with only the one "other Hadoop" flow competing on the uplinks,
        // shared mode halves the pipe (75 Mbps) while Q1 still gives the
        // shuffle 100 Mbps: a modest ~1.33x win vs the >3x contended case.
        let o = run_example3(0);
        assert!(o.queued_secs < o.shared_secs);
        assert!(o.speedup < 1.6, "speedup {}", o.speedup);
    }

    #[test]
    fn shuffle_rate_math() {
        // 640MB at Q1=100Mbps=12.5MB/s -> 51.2s
        let o = run_example3(8);
        assert!((o.queued_secs - 51.2).abs() < 1e-6, "got {}", o.queued_secs);
    }

    #[test]
    fn speedup_grows_with_background() {
        let a = run_example3(2);
        let b = run_example3(10);
        assert!(b.speedup > a.speedup);
    }
}
