//! Table I / Fig. 5 driver: the Wordcount & Sort sweeps.
//!
//! For each data size and scheduler, a fresh 6-node / 2-switch cluster
//! (the paper's testbed: 64MB blocks, 3 replicas, 100 Mbps links) runs
//! one job with seeded background load through the scenario layer's
//! two-phase pipeline ([`SimSession::run_job`]):
//!
//! 1. **Map phase** — scheduled at t=0, executed through the DES engine
//!    (HDS/BAR transfers contend in the flow network; BASS/Pre-BASS use
//!    their slot reservations).
//! 2. **Reduce phase** — gated at the slowstart point (the paper runs
//!    Hadoop 1.x defaults; we use the job's `slowstart` fraction of map
//!    finishes), with shuffle-source hints set to the node holding the
//!    most map output.
//!
//! Identical seeds per data size mean every scheduler sees the exact
//! same block layout, initial load, and background flows: all deltas are
//! scheduling. Every (size, scheduler) cell is a hermetic session, so
//! the sweep fans out across `cfg.threads` workers with results
//! bitwise-identical to a serial run.

use crate::metrics::JobMetrics;
use crate::runtime::CostModel;
use crate::scenario::{
    cell_seed, parallel_map, BackgroundSpec, InitialLoad, ScenarioSpec, SimSession,
    TopologyShape, WorkloadSpec,
};
use crate::workload::JobKind;

use super::fixtures::SchedulerKind;

/// Sweep configuration (defaults = the paper's setup).
#[derive(Debug, Clone)]
pub struct Table1Config {
    pub kind: JobKind,
    pub sizes_mb: Vec<f64>,
    pub schedulers: Vec<SchedulerKind>,
    pub seed: u64,
    pub n_switches: usize,
    pub hosts_per_switch: usize,
    pub link_mbps: f64,
    pub slot_secs: f64,
    pub replication: usize,
    pub reduces: usize,
    /// Max initial node busy time sampled per node (s).
    pub max_initial_idle: f64,
    /// Permanent background flows.
    pub bg_flows: usize,
    /// Nominal per-background-flow rate (MB/s) for the controller view.
    pub bg_rate_mb_s: f64,
    /// Reduce slowstart fraction.
    pub slowstart: f64,
    /// Worker threads for the sweep grid (1 = serial, same results).
    pub threads: usize,
}

impl Table1Config {
    pub fn paper(kind: JobKind) -> Self {
        Self {
            kind,
            sizes_mb: vec![150.0, 300.0, 600.0, 1024.0, 5120.0],
            schedulers: vec![SchedulerKind::Bass, SchedulerKind::Bar, SchedulerKind::Hds],
            seed: 2014,
            n_switches: 2,
            hosts_per_switch: 3,
            link_mbps: 100.0,
            slot_secs: 1.0,
            replication: 3,
            reduces: 2,
            max_initial_idle: 25.0,
            bg_flows: 3,
            bg_rate_mb_s: 3.0,
            slowstart: 0.5,
            threads: 1,
        }
    }

    /// The scenario one (size, scheduler) cell expands to. Deterministic
    /// per (seed, size): identical layout across schedulers.
    pub fn cell_spec(&self, data_mb: f64, kind: SchedulerKind) -> ScenarioSpec {
        let mut s = ScenarioSpec::new(
            format!("table1-{}-{}MB", self.kind.label(), data_mb as u64),
            TopologyShape::Tree {
                switches: self.n_switches,
                hosts_per_switch: self.hosts_per_switch,
                edge_mbps: self.link_mbps,
                uplink_mbps: self.link_mbps,
            },
            WorkloadSpec::Job { kind: self.kind, data_mb },
        );
        s.scheduler = kind;
        s.slot_secs = self.slot_secs;
        s.replication = self.replication;
        s.reduces = self.reduces;
        s.slowstart = self.slowstart;
        s.seed = cell_seed(self.seed, data_mb);
        s.initial = InitialLoad::Sampled { max_secs: self.max_initial_idle };
        s.background = BackgroundSpec { flows: self.bg_flows, rate_mb_s: self.bg_rate_mb_s };
        s
    }
}

/// One Table I cell group.
#[derive(Debug, Clone)]
pub struct Table1Row {
    pub scheduler: &'static str,
    pub data_mb: f64,
    pub metrics: JobMetrics,
}

/// Run the full sweep, fanning cells across `cfg.threads` workers.
pub fn run_table1(cfg: &Table1Config, cost: &CostModel) -> Vec<Table1Row> {
    let points: Vec<(f64, SchedulerKind)> = cfg
        .sizes_mb
        .iter()
        .flat_map(|&size| cfg.schedulers.iter().map(move |&kind| (size, kind)))
        .collect();
    parallel_map(points, cfg.threads, |(size, kind)| Table1Row {
        scheduler: kind.label(),
        data_mb: size,
        metrics: run_cell(cfg, size, kind, cost),
    })
}

/// Run one (size, scheduler) cell.
pub fn run_cell(
    cfg: &Table1Config,
    data_mb: f64,
    kind: SchedulerKind,
    cost: &CostModel,
) -> JobMetrics {
    SimSession::new(&cfg.cell_spec(data_mb, kind)).run_job(cost)
}

/// Bench helper: one BASS cell (used by `benches/table1_wordcount.rs`).
pub fn run_cell_for_bench(cfg: &Table1Config, data_mb: f64, cost: &CostModel) -> JobMetrics {
    run_cell(cfg, data_mb, SchedulerKind::Bass, cost)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(kind: JobKind) -> Table1Config {
        let mut c = Table1Config::paper(kind);
        c.sizes_mb = vec![150.0, 600.0];
        c
    }

    #[test]
    fn sweep_produces_all_rows() {
        let cfg = small_cfg(JobKind::Wordcount);
        let rows = run_table1(&cfg, &CostModel::rust_only());
        assert_eq!(rows.len(), 2 * 3);
        for r in &rows {
            assert!(r.metrics.jt > 0.0);
            assert!(r.metrics.mt > 0.0);
            assert!((0.0..=1.0).contains(&r.metrics.lr));
        }
    }

    #[test]
    fn bass_wins_the_table_shape() {
        // the paper's core claim: BASS JT <= BAR JT <= HDS JT (shape, not
        // absolute seconds) at every sweep point
        for kind in [JobKind::Wordcount, JobKind::Sort] {
            let cfg = small_cfg(kind);
            let rows = run_table1(&cfg, &CostModel::rust_only());
            for &size in &cfg.sizes_mb {
                let jt = |name: &str| {
                    rows.iter()
                        .find(|r| r.scheduler == name && r.data_mb == size)
                        .unwrap()
                        .metrics
                        .jt
                };
                let (bass, bar, hds) = (jt("BASS"), jt("BAR"), jt("HDS"));
                // one slot of tolerance per phase: TS quantization can
                // cost BASS up to slot_secs on ties (paper's 1s slots too)
                let tol = 2.0 * cfg.slot_secs;
                assert!(
                    bass <= bar + tol && bar <= hds + tol,
                    "{kind:?} {size}MB: BASS={bass:.1} BAR={bar:.1} HDS={hds:.1}"
                );
            }
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let cfg = small_cfg(JobKind::Sort);
        let a = run_cell(&cfg, 150.0, SchedulerKind::Bass, &CostModel::rust_only());
        let b = run_cell(&cfg, 150.0, SchedulerKind::Bass, &CostModel::rust_only());
        assert_eq!(a, b);
    }

    #[test]
    fn threaded_sweep_is_bitwise_identical() {
        let serial = small_cfg(JobKind::Sort);
        let mut fanned = small_cfg(JobKind::Sort);
        fanned.threads = 4;
        let cost = CostModel::rust_only();
        let a = run_table1(&serial, &cost);
        let b = run_table1(&fanned, &cost);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.scheduler, y.scheduler);
            assert_eq!(x.data_mb, y.data_mb);
            assert_eq!(x.metrics, y.metrics);
        }
    }

    #[test]
    fn cell_spec_is_identical_across_schedulers() {
        // the sweep's control variable: same seed/layout, scheduler only
        let cfg = small_cfg(JobKind::Sort);
        let a = cfg.cell_spec(600.0, SchedulerKind::Bass);
        let b = cfg.cell_spec(600.0, SchedulerKind::Hds);
        assert_eq!(a.seed, b.seed);
        assert_eq!(a.slowstart, b.slowstart);
        assert_ne!(a.scheduler, b.scheduler);
    }
}
