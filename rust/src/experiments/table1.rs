//! Table I / Fig. 5 driver: the Wordcount & Sort sweeps.
//!
//! For each data size and scheduler, a fresh 6-node / 2-switch cluster
//! (the paper's testbed: 64MB blocks, 3 replicas, 100 Mbps links) runs
//! one job with seeded background load, in two phases:
//!
//! 1. **Map phase** — scheduled at t=0, executed through the DES engine
//!    (HDS/BAR transfers contend in the flow network; BASS/Pre-BASS use
//!    their slot reservations).
//! 2. **Reduce phase** — gated at the slowstart point (the paper runs
//!    Hadoop 1.x defaults; we use the job's `slowstart` fraction of map
//!    finishes), with shuffle-source hints set to the node holding the
//!    most map output.
//!
//! Identical seeds per data size mean every scheduler sees the exact
//! same block layout, initial load, and background flows: all deltas are
//! scheduling.

use crate::cluster::Ledger;
use crate::hdfs::Namenode;
use crate::mapreduce::TaskSpec;
use crate::metrics::JobMetrics;
use crate::runtime::CostModel;
use crate::sched::SchedCtx;
use crate::sdn::Controller;
use crate::sim::{Engine, FlowNet, TaskRecord};
use crate::topology::builders::tree_cluster;
use crate::topology::NodeId;
use crate::util::{Secs, XorShift};
use crate::workload::{BackgroundLoad, JobKind, WorkloadBuilder};

use super::fixtures::SchedulerKind;

/// Sweep configuration (defaults = the paper's setup).
#[derive(Debug, Clone)]
pub struct Table1Config {
    pub kind: JobKind,
    pub sizes_mb: Vec<f64>,
    pub schedulers: Vec<SchedulerKind>,
    pub seed: u64,
    pub n_switches: usize,
    pub hosts_per_switch: usize,
    pub link_mbps: f64,
    pub slot_secs: f64,
    pub replication: usize,
    pub reduces: usize,
    /// Max initial node busy time sampled per node (s).
    pub max_initial_idle: f64,
    /// Permanent background flows.
    pub bg_flows: usize,
    /// Nominal per-background-flow rate (MB/s) for the controller view.
    pub bg_rate_mb_s: f64,
    /// Reduce slowstart fraction.
    pub slowstart: f64,
}

impl Table1Config {
    pub fn paper(kind: JobKind) -> Self {
        Self {
            kind,
            sizes_mb: vec![150.0, 300.0, 600.0, 1024.0, 5120.0],
            schedulers: vec![SchedulerKind::Bass, SchedulerKind::Bar, SchedulerKind::Hds],
            seed: 2014,
            n_switches: 2,
            hosts_per_switch: 3,
            link_mbps: 100.0,
            slot_secs: 1.0,
            replication: 3,
            reduces: 2,
            max_initial_idle: 25.0,
            bg_flows: 3,
            bg_rate_mb_s: 3.0,
            slowstart: 0.5,
        }
    }
}

/// One Table I cell group.
#[derive(Debug, Clone)]
pub struct Table1Row {
    pub scheduler: &'static str,
    pub data_mb: f64,
    pub metrics: JobMetrics,
}

/// Run the full sweep.
pub fn run_table1(cfg: &Table1Config, cost: &CostModel) -> Vec<Table1Row> {
    let mut rows = Vec::new();
    for &size in &cfg.sizes_mb {
        for &kind in &cfg.schedulers {
            let metrics = run_cell(cfg, size, kind, cost);
            rows.push(Table1Row { scheduler: kind.label(), data_mb: size, metrics });
        }
    }
    rows
}

/// Run one (size, scheduler) cell.
pub fn run_cell(
    cfg: &Table1Config,
    data_mb: f64,
    kind: SchedulerKind,
    cost: &CostModel,
) -> JobMetrics {
    // deterministic per (seed, size): identical layout across schedulers
    let cell_seed = cfg.seed ^ (data_mb as u64).wrapping_mul(0x9E37_79B9);
    let mut rng = XorShift::new(cell_seed);

    let (topo, nodes) =
        tree_cluster(cfg.n_switches, cfg.hosts_per_switch, cfg.link_mbps, cfg.link_mbps);
    let caps: Vec<f64> = topo.links.iter().map(|l| l.capacity_mbps).collect();
    let mut ctrl = Controller::new(topo, cfg.slot_secs);
    let mut net = FlowNet::new(&caps);
    let bg = BackgroundLoad::sample(
        &nodes,
        cfg.max_initial_idle,
        cfg.bg_flows,
        cfg.bg_rate_mb_s,
        &mut rng,
    );
    bg.install(&mut ctrl, &mut net);

    let mut nn = Namenode::new();
    let mut builder = WorkloadBuilder::new(cfg.kind);
    builder.replication = cfg.replication;
    builder.reduces = cfg.reduces;
    let job = builder.build(0, data_mb, &nodes, &mut nn, &mut rng);
    let maps: Vec<TaskSpec> = job.maps().cloned().collect();
    let mut reduces: Vec<TaskSpec> = job.reduces().cloned().collect();

    let mut ledger_init = vec![Secs::ZERO; nodes.len()];
    for (i, &t) in bg.initial_idle.iter().enumerate() {
        ledger_init[i] = t;
    }
    let mut ledger = Ledger::with_initial(ledger_init.clone());
    let mut sched = kind.make();

    // ---- phase 1: maps ----
    let map_assignment = {
        let mut ctx = SchedCtx {
            controller: &mut ctrl,
            namenode: &nn,
            ledger: &mut ledger,
            authorized: nodes.clone(),
            now: Secs::ZERO,
            cost,
            node_speed: Vec::new(),
        };
        sched.schedule(&maps, None, &mut ctx)
    };
    let lr = map_assignment.locality_ratio();
    let mut engine = Engine::new(net.clone(), ledger_init.clone());
    engine.load(&map_assignment);
    let map_records = engine.run();

    // ---- slowstart gate + shuffle source hints ----
    let gate = slowstart_gate(&map_records, cfg.slowstart);
    let hint = shuffle_majority_node(&map_records, &maps, nodes.len());
    for r in &mut reduces {
        r.src_hint = Some(hint);
    }

    // ---- phase 2: reduces, from the executed map state ----
    let mut reduce_init = ledger_init;
    for r in &map_records {
        if reduce_init[r.node.0] < r.finish {
            reduce_init[r.node.0] = r.finish;
        }
    }
    let mut ledger2 = Ledger::with_initial(reduce_init.clone());
    let reduce_assignment = {
        let mut ctx = SchedCtx {
            controller: &mut ctrl,
            namenode: &nn,
            ledger: &mut ledger2,
            authorized: nodes.clone(),
            now: gate,
            cost,
            node_speed: Vec::new(),
        };
        sched.schedule(&reduces, Some(gate), &mut ctx)
    };
    let mut engine2 = Engine::new(net, reduce_init);
    engine2.load(&reduce_assignment);
    let reduce_records = engine2.run();

    let mut all = map_records;
    all.extend(reduce_records);
    let mut m = JobMetrics::from_records(&all, Secs::ZERO, Some(gate));
    m.lr = lr;
    m
}

/// Bench helper: one BASS cell (used by `benches/table1_wordcount.rs`).
pub fn run_cell_for_bench(cfg: &Table1Config, data_mb: f64, cost: &CostModel) -> JobMetrics {
    run_cell(cfg, data_mb, SchedulerKind::Bass, cost)
}

/// Time at which `frac` of the maps have finished.
fn slowstart_gate(map_records: &[TaskRecord], frac: f64) -> Secs {
    let mut fins: Vec<Secs> = map_records.iter().map(|r| r.finish).collect();
    fins.sort();
    let k = ((fins.len() as f64 * frac).ceil() as usize).clamp(1, fins.len());
    fins[k - 1]
}

/// Node holding the most map output (the reduces' shuffle source hint).
fn shuffle_majority_node(
    map_records: &[TaskRecord],
    maps: &[TaskSpec],
    n_nodes: usize,
) -> NodeId {
    let mut out_mb = vec![0.0f64; n_nodes];
    for r in map_records {
        let t = maps.iter().find(|t| t.id == r.task).expect("map record");
        out_mb[r.node.0] += t.output_mb;
    }
    let best = out_mb
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0);
    NodeId(best)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(kind: JobKind) -> Table1Config {
        let mut c = Table1Config::paper(kind);
        c.sizes_mb = vec![150.0, 600.0];
        c
    }

    #[test]
    fn sweep_produces_all_rows() {
        let cfg = small_cfg(JobKind::Wordcount);
        let rows = run_table1(&cfg, &CostModel::rust_only());
        assert_eq!(rows.len(), 2 * 3);
        for r in &rows {
            assert!(r.metrics.jt > 0.0);
            assert!(r.metrics.mt > 0.0);
            assert!((0.0..=1.0).contains(&r.metrics.lr));
        }
    }

    #[test]
    fn bass_wins_the_table_shape() {
        // the paper's core claim: BASS JT <= BAR JT <= HDS JT (shape, not
        // absolute seconds) at every sweep point
        for kind in [JobKind::Wordcount, JobKind::Sort] {
            let cfg = small_cfg(kind);
            let rows = run_table1(&cfg, &CostModel::rust_only());
            for &size in &cfg.sizes_mb {
                let jt = |name: &str| {
                    rows.iter()
                        .find(|r| r.scheduler == name && r.data_mb == size)
                        .unwrap()
                        .metrics
                        .jt
                };
                let (bass, bar, hds) = (jt("BASS"), jt("BAR"), jt("HDS"));
                // one slot of tolerance per phase: TS quantization can
                // cost BASS up to slot_secs on ties (paper's 1s slots too)
                let tol = 2.0 * cfg.slot_secs;
                assert!(
                    bass <= bar + tol && bar <= hds + tol,
                    "{kind:?} {size}MB: BASS={bass:.1} BAR={bar:.1} HDS={hds:.1}"
                );
            }
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let cfg = small_cfg(JobKind::Sort);
        let a = run_cell(&cfg, 150.0, SchedulerKind::Bass, &CostModel::rust_only());
        let b = run_cell(&cfg, 150.0, SchedulerKind::Bass, &CostModel::rust_only());
        assert_eq!(a, b);
    }

    #[test]
    fn slowstart_gate_quantile() {
        use crate::mapreduce::TaskId;
        let recs: Vec<TaskRecord> = (0..4)
            .map(|i| TaskRecord {
                task: TaskId(i),
                node: NodeId(0),
                picked_at: Secs::ZERO,
                input_ready: Secs::ZERO,
                compute_start: Secs::ZERO,
                finish: Secs((i + 1) as f64 * 10.0),
                is_local: true,
                is_map: true,
            })
            .collect();
        assert_eq!(slowstart_gate(&recs, 0.5), Secs(20.0));
        assert_eq!(slowstart_gate(&recs, 1.0), Secs(40.0));
        assert_eq!(slowstart_gate(&recs, 0.0), Secs(10.0));
    }
}
