//! Arrival-rate sweep: schedulers under a rising online job load.
//!
//! The paper evaluates one job at a time; this family submits a Poisson
//! stream of Wordcount/Sort jobs to one shared cluster and sweeps the
//! arrival rate from sparse (jobs never overlap — every scheduler
//! behaves exactly as in isolation) to heavy (jobs pile onto the same
//! slots, calendar windows and links). Every scheduler at one rate faces
//! the *identical* arrival trace (one stream seed per rate), so all
//! deltas are scheduling policy. The headline observable is the **mean
//! job slowdown** — stream completion time over the same job's isolated
//! run — which sits at exactly 1.0 in the sparse limit and grows
//! strictly above 1.0 under contention. See EXPERIMENTS.md.

use crate::runtime::CostModel;
use crate::scenario::{
    parallel_map, run_stream, BackgroundSpec, InitialLoad, ScenarioSpec, SimSession,
    StreamSpec, TopologyShape, WorkloadSpec,
};

use super::fixtures::SchedulerKind;

/// One executed (arrival rate, scheduler) sweep point.
#[derive(Debug, Clone)]
pub struct StreamPoint {
    /// Mean inter-arrival gap of this point (seconds).
    pub mean_interarrival_secs: f64,
    pub scheduler: &'static str,
    pub jobs: usize,
    pub mean_jt: f64,
    pub p50_jt: f64,
    pub p95_jt: f64,
    pub mean_slowdown: f64,
    pub max_slowdown: f64,
    /// Stream makespan: last finish minus first submission.
    pub makespan: f64,
    /// Jobs that waited in the admission queue.
    pub queued: usize,
}

/// The cluster one stream point runs on: a 12-node shared tree with
/// background traffic (the coordinator's regime, scaled up a little).
pub fn stream_cluster(kind: SchedulerKind) -> ScenarioSpec {
    let mut s = ScenarioSpec::new(
        "stream",
        TopologyShape::Tree {
            switches: 4,
            hosts_per_switch: 3,
            edge_mbps: 100.0,
            uplink_mbps: 400.0,
        },
        WorkloadSpec::None,
    );
    s.scheduler = kind;
    s.replication = 3;
    s.reduces = 2;
    s.seed = 2014;
    s.initial = InitialLoad::Sampled { max_secs: 0.0 };
    s.background = BackgroundSpec { flows: 3, rate_mb_s: 2.0 };
    s
}

/// The stream each point plays: `jobs` Poisson arrivals at the given
/// mean gap, sizes from the paper's sweep, one trace seed per rate.
pub fn stream_spec(mean_interarrival_secs: f64, jobs: usize) -> StreamSpec {
    StreamSpec {
        jobs,
        mean_interarrival_secs,
        sizes_mb: vec![150.0, 300.0, 600.0],
        seed: 4242,
        ..StreamSpec::defaults()
    }
}

/// Run the sweep over `interarrivals x {BASS, BAR, HDS}` on up to
/// `threads` workers (each point is a hermetic session; results are
/// bitwise-identical to a serial run).
pub fn run_stream_sweep(
    interarrivals: &[f64],
    jobs: usize,
    cost: &CostModel,
    threads: usize,
) -> Vec<StreamPoint> {
    run_stream_sweep_with(&stream_spec(0.0, jobs), interarrivals, cost, threads)
}

/// [`run_stream_sweep`] with an explicit stream template (the `[stream]`
/// config route): `base` fixes jobs/sizes/admission/seed, each point
/// overrides the mean inter-arrival gap.
pub fn run_stream_sweep_with(
    base: &StreamSpec,
    interarrivals: &[f64],
    cost: &CostModel,
    threads: usize,
) -> Vec<StreamPoint> {
    let points: Vec<(f64, SchedulerKind)> = interarrivals
        .iter()
        .flat_map(|&gap| {
            [SchedulerKind::Bass, SchedulerKind::Bar, SchedulerKind::Hds]
                .into_iter()
                .map(move |k| (gap, k))
        })
        .collect();
    parallel_map(points, threads, |(gap, kind)| {
        let spec = StreamSpec { mean_interarrival_secs: gap, ..base.clone() };
        let mut sess = SimSession::new(&stream_cluster(kind));
        let out = run_stream(&mut sess, spec.submissions(), spec.policy(), cost);
        StreamPoint {
            mean_interarrival_secs: gap,
            scheduler: kind.label(),
            jobs: out.jobs.len(),
            mean_jt: out.stats.mean_jt,
            p50_jt: out.stats.p50_jt,
            p95_jt: out.stats.p95_jt,
            mean_slowdown: out.stats.mean_slowdown,
            max_slowdown: out.stats.max_slowdown,
            makespan: out.makespan,
            queued: out.queued_jobs,
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_jobs() -> usize {
        match std::env::var("BASS_BENCH_QUICK") {
            Ok(_) => 4,
            Err(_) => 8,
        }
    }

    #[test]
    fn high_arrival_rate_slows_every_scheduler_down() {
        // the acceptance observable: mean slowdown strictly > 1 under
        // pressure, for every scheduler
        let cost = CostModel::rust_only();
        let jobs = quick_jobs();
        let pts = run_stream_sweep(&[8.0], jobs, &cost, 2);
        assert_eq!(pts.len(), 3);
        for p in &pts {
            assert_eq!(p.jobs, jobs);
            assert!(p.mean_jt > 0.0);
            assert!(
                p.mean_slowdown > 1.0,
                "{}: high arrival rate must contend (mean slowdown {})",
                p.scheduler,
                p.mean_slowdown
            );
            assert!(p.p95_jt >= p.p50_jt);
            assert!(p.max_slowdown >= p.mean_slowdown);
        }
    }

    #[test]
    fn sparse_arrivals_are_exactly_uncontended() {
        // deterministically sparse: fixed gaps far beyond any makespan.
        // Wordcount-150 jobs make the equality rigorous for every
        // scheduler: 3 maps fit in one wave and the worst-case remote
        // pull (3 pulls sharing one source edge plus capped background,
        // >= 3.5 MB/s each -> <= 18.3s) always lands before the earliest
        // possible slowstart gate (22s map compute), so no same-job
        // flow overlap exists and the shared-engine and phase-split
        // models coincide — slowdown is exactly 1.0 (the differential
        // pin at the sweep level).
        use crate::scenario::AdmissionPolicy;
        use crate::workload::JobKind;
        let cost = CostModel::rust_only();
        for kind in [SchedulerKind::Bass, SchedulerKind::Bar, SchedulerKind::Hds] {
            let mut sess = SimSession::new(&stream_cluster(kind));
            let subs: Vec<crate::scenario::Submission> = (0..3)
                .map(|i| crate::scenario::Submission {
                    at_secs: 10.0 + i as f64 * 10_000.0,
                    body: crate::scenario::SubmissionBody::Generated {
                        kind: JobKind::Wordcount,
                        data_mb: 150.0,
                    },
                    tenant: None,
                })
                .collect();
            let out = run_stream(&mut sess, subs, AdmissionPolicy::default(), &cost);
            for j in &out.jobs {
                assert_eq!(
                    j.slowdown, 1.0,
                    "{}: sparse job {} contended (jt {} vs isolated {})",
                    kind.label(),
                    j.name,
                    j.metrics.jt,
                    j.isolated_jt
                );
            }
            assert_eq!(out.stats.mean_slowdown, 1.0, "{}", kind.label());
            assert_eq!(out.queued_jobs, 0);
        }
    }

    #[test]
    fn schedulers_share_the_arrival_trace_per_rate() {
        let a = stream_spec(30.0, 6).submissions();
        let b = stream_spec(30.0, 6).submissions();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at_secs, y.at_secs);
        }
    }

    #[test]
    fn sweep_is_deterministic_and_thread_invariant() {
        let cost = CostModel::rust_only();
        let serial = run_stream_sweep(&[20.0], 4, &cost, 1);
        let fanned = run_stream_sweep(&[20.0], 4, &cost, 3);
        assert_eq!(serial.len(), fanned.len());
        for (a, b) in serial.iter().zip(&fanned) {
            assert_eq!(a.scheduler, b.scheduler);
            assert_eq!(a.mean_jt, b.mean_jt);
            assert_eq!(a.mean_slowdown, b.mean_slowdown);
            assert_eq!(a.makespan, b.makespan);
        }
    }
}
