//! Estimate-error sweep: where does BASS's edge survive imperfect
//! information?
//!
//! Every other sweep hands the schedulers *clairvoyant* bandwidth. This
//! one runs the churn scenario under the measured control plane
//! (DESIGN.md §12): link estimates come from seeded noisy probes on a
//! `probe_period` grid, and the closed loop renegotiates drifting grants
//! at probe epochs. The two axes — relative estimate error and probe
//! staleness — are exactly the information-quality knobs a real SDN
//! deployment trades against controller load, and the question is how
//! fast BASS's bandwidth-aware margin over BAR/HDS decays as its
//! information degrades. At `noise = 0`, `probe_period -> 0` the plane
//! converges to the clairvoyant baseline (pinned bitwise below), so the
//! sweep's origin cell is the rest of the repo's numbers.

use crate::runtime::CostModel;
use crate::scenario::{parallel_map, MitigationSpec, ScenarioSpec, SimSession};
use crate::sdn::TelemetrySpec;

use super::dynamics::churn_spec;
use super::fixtures::SchedulerKind;

/// Churn level the sweep holds fixed: enough drift that stale or noisy
/// estimates have something to be wrong about.
const ESTIMATE_CHURN: f64 = 0.5;

/// One executed (noise, probe period, scheduler) sweep point.
#[derive(Debug, Clone)]
pub struct EstimatePoint {
    /// Relative probe noise sigma (`sample = truth * (1 + noise*N(0,1))`).
    pub noise: f64,
    /// Seconds between probe sweeps (`0` = continuous).
    pub probe_period: f64,
    pub scheduler: &'static str,
    pub makespan: f64,
    pub locality: f64,
    /// Probe sweeps the telemetry plane executed.
    pub probes: usize,
    /// Grants the closed loop actually moved (drifting renegotiations).
    pub reallocations: usize,
    pub completed: usize,
    pub tasks: usize,
}

/// The scenario one (noise, period, scheduler) point expands to: the
/// churn-sweep cluster at a fixed mid churn, scheduled from measured
/// bandwidth with the reallocation loop closed. Mitigation stays inert so
/// information quality is the only axis (the checkpoint clock still runs
/// — the closed loop needs it).
pub fn estimate_spec(noise: f64, period: f64, kind: SchedulerKind) -> ScenarioSpec {
    let mut s = churn_spec(ESTIMATE_CHURN, kind);
    s.name = format!("estimate-n{noise:.2}-p{period:.1}");
    s.mitigation = Some(MitigationSpec::off());
    s.telemetry = Some(TelemetrySpec {
        noise,
        probe_period: period,
        reallocate: true,
        ..TelemetrySpec::measured()
    });
    s
}

/// Run the estimate sweep over `noises` x `periods` x {BASS, BAR, HDS},
/// fanned across `threads` workers (bitwise-identical to serial).
pub fn run_estimate(
    noises: &[f64],
    periods: &[f64],
    cost: &CostModel,
    threads: usize,
) -> Vec<EstimatePoint> {
    let points: Vec<(f64, f64, SchedulerKind)> = noises
        .iter()
        .flat_map(|&n| {
            periods.iter().flat_map(move |&p| {
                [SchedulerKind::Bass, SchedulerKind::Bar, SchedulerKind::Hds]
                    .into_iter()
                    .map(move |k| (n, p, k))
            })
        })
        .collect();
    parallel_map(points, threads, |(noise, period, kind)| {
        let spec = estimate_spec(noise, period, kind);
        let sess = SimSession::new(&spec);
        let out = sess.run_mitigated(cost);
        EstimatePoint {
            noise,
            probe_period: period,
            scheduler: kind.label(),
            makespan: out.makespan,
            locality: out.locality,
            probes: out.probes,
            reallocations: out.reallocations,
            completed: out.records.len(),
            tasks: out.submitted.len(),
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Exact tracking limit of the plane: continuous probes, zero noise,
    /// `alpha = 1` (adopt each sample bit-exactly).
    fn exact_spec() -> TelemetrySpec {
        TelemetrySpec {
            probe_period: 0.0,
            noise: 0.0,
            alpha: 1.0,
            ..TelemetrySpec::measured()
        }
    }

    #[test]
    fn exact_continuous_estimates_reproduce_the_clairvoyant_run() {
        // noise = 0, probe_period -> 0, alpha = 1: every scheduling
        // instant sees estimates bit-equal to the truth, so the Measured
        // view must reproduce the Oracle run exactly — even under churn
        let cost = CostModel::rust_only();
        for kind in [SchedulerKind::Bass, SchedulerKind::Bar, SchedulerKind::Hds] {
            let mut measured = churn_spec(ESTIMATE_CHURN, kind);
            measured.telemetry = Some(exact_spec());
            let m = SimSession::new(&measured).run_dynamic(&cost);

            let clairvoyant = churn_spec(ESTIMATE_CHURN, kind);
            let c = SimSession::new(&clairvoyant).run_dynamic(&cost);

            assert!(m.probes > 0, "{}: the plane actually probed", kind.label());
            assert_eq!(c.probes, 0);
            assert_eq!(
                m.makespan.to_bits(),
                c.makespan.to_bits(),
                "{}: bitwise convergence",
                kind.label()
            );
            assert_eq!(m.records.len(), c.records.len());
            for (a, b) in m.records.iter().zip(&c.records) {
                assert_eq!(a.task, b.task);
                assert_eq!(a.node, b.node);
                assert_eq!(a.finish, b.finish);
            }
        }
    }

    #[test]
    fn closed_loop_is_idempotent_without_drift() {
        // zero churn: renegotiations re-find the identical windows, so
        // the loop closes but never moves a grant
        let cost = CostModel::rust_only();
        let mut spec = estimate_spec(0.0, 2.0, SchedulerKind::Bass);
        spec.dynamics = Some(crate::scenario::DynamicsSpec::churn(0.0));
        let out = SimSession::new(&spec).run_mitigated(&cost);
        assert!(out.probes > 0);
        assert_eq!(out.reallocations, 0, "no drift, no reallocation");
        assert!(out.reallocs.is_empty());
        assert_eq!(out.records.len(), out.submitted.len());
    }

    #[test]
    fn sweep_is_deterministic_and_thread_invariant() {
        let cost = CostModel::rust_only();
        let serial = run_estimate(&[0.0, 0.3], &[2.0], &cost, 1);
        let fanned = run_estimate(&[0.0, 0.3], &[2.0], &cost, 3);
        assert_eq!(serial.len(), fanned.len());
        for (a, b) in serial.iter().zip(&fanned) {
            assert_eq!(a.scheduler, b.scheduler);
            assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
            assert_eq!(a.probes, b.probes);
            assert_eq!(a.reallocations, b.reallocations);
        }
    }

    #[test]
    fn grid_is_complete_and_every_point_finishes_the_wave() {
        let pts = run_estimate(&[0.0, 0.4], &[1.0, 8.0], &CostModel::rust_only(), 2);
        assert_eq!(pts.len(), 2 * 2 * 3);
        for p in &pts {
            assert_eq!(p.completed, p.tasks, "{}: every task completes", p.scheduler);
            assert!(p.makespan.is_finite() && p.makespan > 0.0);
            assert!((0.0..=1.0).contains(&p.locality));
            assert!(p.probes > 0, "telemetry ran at every point");
        }
        // slower probes = fewer sweeps, at every noise level
        let probes_at = |noise: f64, period: f64| {
            pts.iter()
                .find(|p| p.noise == noise && p.probe_period == period && p.scheduler == "BASS")
                .unwrap()
                .probes
        };
        assert!(probes_at(0.0, 1.0) >= probes_at(0.0, 8.0));
    }

    #[test]
    fn schedulers_share_the_cell_conditions() {
        // per cell the incident timeline, probe seed and noise draw are
        // scheduler-independent: every delta is policy
        let a = estimate_spec(0.3, 4.0, SchedulerKind::Bass);
        let b = estimate_spec(0.3, 4.0, SchedulerKind::Hds);
        assert_eq!(a.dynamics, b.dynamics);
        assert_eq!(a.seed, b.seed);
        assert_eq!(a.telemetry, b.telemetry);
    }
}
