//! Churn sweep: BASS vs BAR vs HDS as cluster conditions worsen.
//!
//! The paper's evaluation is static; this family injects the conditions
//! its premise cares about — node failures, link degradation, stragglers
//! and cross traffic — at churn levels swept from 0 (the static cluster)
//! to heavy, and compares makespan, locality and reassignment volume
//! across the three schedulers. All schedulers at one level face the
//! *identical* incident timeline (one dynamics seed per level), so every
//! delta is scheduling policy. See EXPERIMENTS.md for findings.

use crate::runtime::CostModel;
use crate::scenario::{
    parallel_map, BackgroundSpec, DynamicsSpec, InitialLoad, MitigationSpec, ScenarioSpec,
    SimSession, TopologyShape, WorkloadSpec,
};

use super::fixtures::SchedulerKind;

/// One executed (churn level, scheduler) sweep point.
#[derive(Debug, Clone)]
pub struct ChurnPoint {
    pub churn: f64,
    pub scheduler: &'static str,
    /// Speculation mode label of the mitigation policy the point ran
    /// under (`"off"` = the plain dynamics path).
    pub mitigation: &'static str,
    pub makespan: f64,
    pub locality: f64,
    pub reassignments: usize,
    pub rounds: usize,
    pub completed: usize,
    pub tasks: usize,
    /// Task-rounds deferred on unreadable blocks (every holder down).
    pub deferrals: usize,
    /// Peak per-round under-replicated block count.
    pub under_replicated_peak: usize,
    /// Duplicate attempts launched by speculative execution.
    pub speculated: usize,
    /// Duels the duplicate won (original killed).
    pub spec_wins: usize,
    /// Nodes evicted by the straggle-factor ceiling.
    pub evictions: usize,
}

/// The scenario one (level, scheduler) point expands to: a 16-node tree
/// in the shared-cluster regime with `DynamicsSpec::churn(level)` on top.
pub fn churn_spec(level: f64, kind: SchedulerKind) -> ScenarioSpec {
    let mut s = ScenarioSpec::new(
        format!("churn-{level:.2}"),
        TopologyShape::Tree {
            switches: 4,
            hosts_per_switch: 4,
            edge_mbps: 100.0,
            uplink_mbps: 1000.0,
        },
        WorkloadSpec::MapWave { tasks: 32, compute_secs: 18.0, output_mb: 8.0 },
    );
    s.scheduler = kind;
    s.replication = 2;
    s.seed = 4242;
    s.initial = InitialLoad::Sampled { max_secs: 15.0 };
    s.background = BackgroundSpec { flows: 4, rate_mb_s: 3.0 };
    s.dynamics = Some(DynamicsSpec::churn(level));
    s
}

/// Run the churn sweep over `levels` x {BASS, BAR, HDS}, fanned across
/// `threads` workers (bitwise-identical to serial).
///
/// `mitigation` is the sweep's reaction policy, applied uniformly so
/// the churn axis stays the only variable per column. The inert
/// [`MitigationSpec::off`] reproduces the plain `run_dynamic` sweep
/// bit-for-bit (the mitigated runner delegates); the incident timeline
/// itself never depends on the mitigation policy, so off/late/bw_aware
/// columns at one level face identical churn.
pub fn run_dynamics(
    levels: &[f64],
    cost: &CostModel,
    threads: usize,
    mitigation: &MitigationSpec,
) -> Vec<ChurnPoint> {
    let points: Vec<(f64, SchedulerKind)> = levels
        .iter()
        .flat_map(|&lv| {
            [SchedulerKind::Bass, SchedulerKind::Bar, SchedulerKind::Hds]
                .into_iter()
                .map(move |k| (lv, k))
        })
        .collect();
    parallel_map(points, threads, |(lv, kind)| {
        let mut spec = churn_spec(lv, kind);
        spec.mitigation = Some(mitigation.clone());
        let sess = SimSession::new(&spec);
        let out = sess.run_mitigated(cost);
        ChurnPoint {
            churn: lv,
            scheduler: kind.label(),
            mitigation: mitigation.speculation.label(),
            makespan: out.makespan,
            locality: out.locality,
            reassignments: out.reassignments,
            rounds: out.rounds,
            completed: out.records.len(),
            tasks: out.submitted.len(),
            deferrals: out.deferrals,
            under_replicated_peak: out.under_replicated_peak,
            speculated: out.speculated,
            spec_wins: out.spec_wins,
            evictions: out.evictions,
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Secs;

    #[test]
    fn zero_churn_matches_static_execution_bitwise() {
        // the whole dynamics pipeline with an empty timeline must be
        // indistinguishable from plain schedule -> execute
        let cost = CostModel::rust_only();
        for kind in [SchedulerKind::Bass, SchedulerKind::Hds] {
            let spec = churn_spec(0.0, kind);
            let sess = SimSession::new(&spec);
            let out = sess.run_dynamic(&cost);

            let mut static_spec = spec.clone();
            static_spec.dynamics = None;
            let mut st = SimSession::new(&static_spec);
            let tasks = st.tasks.clone();
            let a = st.schedule(&tasks, None, Secs::ZERO, &cost);
            let recs = st.execute(&a);

            assert_eq!(out.records.len(), recs.len(), "{}", kind.label());
            let static_ms = recs.iter().map(|r| r.finish.0).fold(0.0, f64::max);
            assert_eq!(out.makespan, static_ms, "{}: bitwise makespan", kind.label());
            assert_eq!(out.reassignments, 0);
            assert_eq!(out.rounds, 1);
            for (d, s) in out.records.iter().zip(&recs) {
                assert_eq!(d.task, s.task);
                assert_eq!(d.node, s.node);
                assert_eq!(d.finish, s.finish);
            }
        }
    }

    #[test]
    fn heavy_churn_completes_all_tasks_for_all_schedulers() {
        let pts = run_dynamics(&[1.0], &CostModel::rust_only(), 1, &MitigationSpec::off());
        assert_eq!(pts.len(), 3);
        for p in &pts {
            assert_eq!(p.completed, p.tasks, "{}: every task completes", p.scheduler);
            assert!(p.makespan > 0.0);
            assert!((0.0..=1.0).contains(&p.locality));
            assert_eq!(p.mitigation, "off");
            assert_eq!(p.speculated, 0);
        }
    }

    #[test]
    fn sweep_is_deterministic_and_thread_invariant() {
        let cost = CostModel::rust_only();
        for mit in [MitigationSpec::off(), MitigationSpec::bw_aware()] {
            let serial = run_dynamics(&[0.0, 1.0], &cost, 1, &mit);
            let fanned = run_dynamics(&[0.0, 1.0], &cost, 3, &mit);
            assert_eq!(serial.len(), fanned.len());
            for (a, b) in serial.iter().zip(&fanned) {
                assert_eq!(a.scheduler, b.scheduler);
                assert_eq!(a.makespan, b.makespan);
                assert_eq!(a.reassignments, b.reassignments);
                assert_eq!(a.speculated, b.speculated);
            }
        }
    }

    #[test]
    fn schedulers_share_the_incident_timeline_per_level() {
        // the control variable: same dynamics seed and spec per level
        let a = churn_spec(1.0, SchedulerKind::Bass);
        let b = churn_spec(1.0, SchedulerKind::Hds);
        assert_eq!(a.dynamics, b.dynamics);
        assert_eq!(a.seed, b.seed);
    }

    #[test]
    fn off_mitigation_column_is_pinned_to_the_plain_sweep() {
        // `speculation = "off"` (the inert spec) must reproduce the
        // unmitigated dynamics runner bit-for-bit — the mitigation axis
        // adds columns, it never perturbs the baseline
        let cost = CostModel::rust_only();
        let pts = run_dynamics(&[1.0], &cost, 1, &MitigationSpec::off());
        for p in &pts {
            let kind = SchedulerKind::parse(p.scheduler).unwrap();
            let sess = SimSession::new(&churn_spec(p.churn, kind));
            let plain = sess.run_dynamic(&cost);
            assert_eq!(p.makespan.to_bits(), plain.makespan.to_bits(), "{}", p.scheduler);
            assert_eq!(p.reassignments, plain.reassignments);
            assert_eq!(p.rounds, plain.rounds);
        }
    }

    #[test]
    fn mitigation_columns_face_the_identical_incident_timeline() {
        // the dynamics seed is independent of the mitigation policy, so
        // off/late/bw_aware columns at one level see the same incidents
        let base = churn_spec(1.0, SchedulerKind::Bass);
        for mit in [MitigationSpec::late(), MitigationSpec::bw_aware()] {
            let mut m = churn_spec(1.0, SchedulerKind::Bass);
            m.mitigation = Some(mit);
            assert_eq!(base.dynamics, m.dynamics);
            assert_eq!(base.seed, m.seed);
        }
    }
}
