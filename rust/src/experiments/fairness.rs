//! Multi-tenant fairness sweep: DRF admission under a shared stream.
//!
//! The stream sweep (`experiments::stream`) treats every job as one
//! anonymous user; this family splits the same Poisson stream across
//! tenants and replaces FIFO admission with dominant-resource fairness
//! over (occupied slots, reserved calendar bandwidth). The default
//! contract is a two-tenant cluster — "prod" (guaranteed class, swept
//! DRF weight) against "batch" (spot class, weight 1) — with jobs
//! attributed round-robin, so every scheduler and every weight faces the
//! identical arrival trace. Headline observables: per-tenant mean/p95
//! slowdown, SLO attainment, Jain's index across tenants, rejected jobs
//! and preemptions. See EXPERIMENTS.md.

use crate::metrics::TenantStats;
use crate::runtime::CostModel;
use crate::scenario::{
    parallel_map, run_stream, SimSession, StreamSpec, TenancySpec, TenantClass, TenantSpec,
};

use super::fixtures::SchedulerKind;
use super::stream::{stream_cluster, stream_spec};

/// One executed (tenancy, arrival rate, scheduler) sweep point.
#[derive(Debug, Clone)]
pub struct FairnessPoint {
    /// Mean inter-arrival gap of this point (seconds).
    pub mean_interarrival_secs: f64,
    pub scheduler: &'static str,
    /// Jobs submitted (completed + rejected).
    pub jobs: usize,
    /// Jobs rejected at admission (infeasible deadline or quota).
    pub rejected: usize,
    /// Spot tasks drained by guaranteed jobs whose deadline was at risk.
    pub preemptions: usize,
    /// Jain's index over the tenants' mean slowdowns.
    pub fairness_jain: f64,
    /// Per-tenant aggregates, in tenancy declaration order.
    pub tenants: Vec<TenantStats>,
}

/// The built-in two-tenant contract: "prod" (guaranteed, the given DRF
/// weight) against "batch" (spot, weight 1), no quotas, no deadlines.
pub fn fairness_tenancy(prod_weight: f64) -> TenancySpec {
    let mut prod = TenantSpec::named("prod");
    prod.weight = prod_weight;
    prod.class = TenantClass::Guaranteed;
    TenancySpec { tenants: vec![prod, TenantSpec::named("batch")] }
}

/// Run the sweep over `weights x interarrivals x {BASS, BAR, HDS}` with
/// the built-in prod/batch pair (`prod` at each swept weight).
pub fn run_fairness_sweep(
    weights: &[f64],
    interarrivals: &[f64],
    jobs: usize,
    cost: &CostModel,
    threads: usize,
) -> Vec<FairnessPoint> {
    weights
        .iter()
        .flat_map(|&w| {
            run_fairness_sweep_with(&fairness_tenancy(w), interarrivals, jobs, cost, threads)
        })
        .collect()
}

/// [`run_fairness_sweep`] with an explicit tenancy (the `[tenants]`
/// config route). Every scheduler at one rate faces the identical
/// arrival trace; jobs carry no tenant tag, so attribution is
/// round-robin over the declared tenants.
pub fn run_fairness_sweep_with(
    tenancy: &TenancySpec,
    interarrivals: &[f64],
    jobs: usize,
    cost: &CostModel,
    threads: usize,
) -> Vec<FairnessPoint> {
    if let Err(e) = tenancy.validate() {
        panic!("invalid tenancy for fairness sweep: {e}");
    }
    let points: Vec<(f64, SchedulerKind)> = interarrivals
        .iter()
        .flat_map(|&gap| {
            [SchedulerKind::Bass, SchedulerKind::Bar, SchedulerKind::Hds]
                .into_iter()
                .map(move |k| (gap, k))
        })
        .collect();
    parallel_map(points, threads, |(gap, kind)| {
        let mut cluster = stream_cluster(kind);
        cluster.tenants = Some(tenancy.clone());
        let spec = stream_spec(gap, jobs);
        let mut sess = SimSession::new(&cluster);
        let out = run_stream(&mut sess, spec.submissions(), spec.policy(), cost);
        FairnessPoint {
            mean_interarrival_secs: gap,
            scheduler: kind.label(),
            jobs: out.jobs.len(),
            rejected: out.rejected_jobs,
            preemptions: out.preemptions.len(),
            fairness_jain: out.fairness_jain,
            tenants: out.tenant_stats.clone(),
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::{AdmissionPolicy, Submission, SubmissionBody};
    use crate::workload::JobKind;

    fn quick_jobs() -> usize {
        match std::env::var("BASS_BENCH_QUICK") {
            Ok(_) => 4,
            Err(_) => 8,
        }
    }

    #[test]
    fn sweep_reports_both_tenants_at_every_point() {
        let cost = CostModel::rust_only();
        let pts = run_fairness_sweep(&[2.0], &[10.0], quick_jobs(), &cost, 2);
        assert_eq!(pts.len(), 3);
        for p in &pts {
            assert_eq!(p.jobs, quick_jobs());
            assert_eq!(p.tenants.len(), 2);
            assert_eq!(p.tenants[0].tenant, "prod");
            assert_eq!(p.tenants[0].weight, 2.0);
            assert_eq!(p.tenants[1].tenant, "batch");
            assert!(p.fairness_jain > 0.0 && p.fairness_jain <= 1.0);
            let submitted: usize = p.tenants.iter().map(|t| t.jobs).sum();
            assert_eq!(submitted, p.jobs, "{}: every job attributed", p.scheduler);
        }
    }

    #[test]
    fn heavier_weight_never_slows_the_prod_tenant_more() {
        // the acceptance observable, made deterministic: identical jobs
        // arrive in one burst onto a one-slot admission gate, alternating
        // prod/batch. At every admission instant no tenant holds
        // anything, so the DRF keys tie at zero and the larger weight
        // wins — all prod jobs admit before any batch job, for every
        // scheduler.
        let cost = CostModel::rust_only();
        for kind in [SchedulerKind::Bass, SchedulerKind::Bar, SchedulerKind::Hds] {
            let mut cluster = stream_cluster(kind);
            cluster.tenants = Some(fairness_tenancy(2.0));
            let mut sess = SimSession::new(&cluster);
            let subs: Vec<Submission> = (0..6)
                .map(|i| Submission {
                    at_secs: i as f64 * 0.001,
                    body: SubmissionBody::Generated {
                        kind: JobKind::Wordcount,
                        data_mb: 150.0,
                    },
                    tenant: None, // round-robin: even = prod, odd = batch
                })
                .collect();
            let policy = AdmissionPolicy { max_active: 1, ..AdmissionPolicy::default() };
            let out = run_stream(&mut sess, subs, policy, &cost);
            let slow = |name: &str| {
                out.tenant_stats
                    .iter()
                    .find(|t| t.tenant == name)
                    .expect("tenant reported")
                    .mean_slowdown
            };
            assert!(
                slow("prod") <= slow("batch"),
                "{}: weight-2 prod slowed more than weight-1 batch ({} > {})",
                kind.label(),
                slow("prod"),
                slow("batch")
            );
            // the one-slot gate serializes the burst, so the later half
            // (all batch) strictly contends
            assert!(slow("batch") > 1.0, "{}: burst must contend", kind.label());
        }
    }

    #[test]
    fn sweep_is_deterministic_and_thread_invariant() {
        let cost = CostModel::rust_only();
        let serial = run_fairness_sweep(&[2.0], &[15.0], 4, &cost, 1);
        let fanned = run_fairness_sweep(&[2.0], &[15.0], 4, &cost, 3);
        assert_eq!(serial.len(), fanned.len());
        for (a, b) in serial.iter().zip(&fanned) {
            assert_eq!(a.scheduler, b.scheduler);
            assert_eq!(a.fairness_jain, b.fairness_jain);
            assert_eq!(a.rejected, b.rejected);
            assert_eq!(a.preemptions, b.preemptions);
            for (x, y) in a.tenants.iter().zip(&b.tenants) {
                assert_eq!(x.mean_slowdown, y.mean_slowdown);
                assert_eq!(x.p95_slowdown, y.p95_slowdown);
            }
        }
    }
}
