//! Fig. 5 driver: JT-vs-data-size curves for both jobs.
//!
//! Thin wrapper over the Table I sweep that reshapes rows into
//! per-scheduler series — the two panels of the paper's Fig. 5. The
//! `threads` knob fans the underlying sweep cells across workers.

use crate::runtime::CostModel;
use crate::workload::JobKind;

use super::table1::{run_table1, Table1Config};

/// One Fig. 5 panel: per-scheduler JT series over the size sweep.
#[derive(Debug, Clone)]
pub struct Fig5Panel {
    pub job: &'static str,
    pub sizes_mb: Vec<f64>,
    /// (scheduler label, JT per size)
    pub series: Vec<(&'static str, Vec<f64>)>,
}

/// Run both panels (Wordcount + Sort) on `threads` sweep workers.
pub fn run_fig5(cost: &CostModel, sizes_mb: Option<Vec<f64>>, threads: usize) -> Vec<Fig5Panel> {
    [JobKind::Wordcount, JobKind::Sort]
        .into_iter()
        .map(|kind| {
            let mut cfg = Table1Config::paper(kind);
            cfg.threads = threads;
            if let Some(s) = &sizes_mb {
                cfg.sizes_mb = s.clone();
            }
            let rows = run_table1(&cfg, cost);
            let series = cfg
                .schedulers
                .iter()
                .map(|k| {
                    let jts = cfg
                        .sizes_mb
                        .iter()
                        .map(|&s| {
                            rows.iter()
                                .find(|r| r.scheduler == k.label() && r.data_mb == s)
                                .expect("row")
                                .metrics
                                .jt
                        })
                        .collect();
                    (k.label(), jts)
                })
                .collect();
            Fig5Panel { job: kind.label(), sizes_mb: cfg.sizes_mb, series }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn panels_have_monotone_jt_in_size() {
        let panels = run_fig5(&CostModel::rust_only(), Some(vec![150.0, 600.0]), 1);
        assert_eq!(panels.len(), 2);
        for p in &panels {
            assert_eq!(p.series.len(), 3);
            for (name, jts) in &p.series {
                assert_eq!(jts.len(), 2);
                assert!(
                    jts[1] > jts[0],
                    "{} {name}: JT should grow with data size: {jts:?}",
                    p.job
                );
            }
        }
    }

    #[test]
    fn threads_do_not_change_the_panels() {
        let cost = CostModel::rust_only();
        let serial = run_fig5(&cost, Some(vec![150.0, 300.0]), 1);
        let fanned = run_fig5(&cost, Some(vec![150.0, 300.0]), 3);
        for (a, b) in serial.iter().zip(&fanned) {
            assert_eq!(a.job, b.job);
            assert_eq!(a.series, b.series);
        }
    }
}
