//! Sustained-load soak sweep: schedulers under a shaped arrival trace.
//!
//! The stream sweep answers "how do schedulers degrade as a Poisson
//! rate rises"; this family answers the capacity question behind it:
//! **how much load can each scheduler sustain** while the tail of the
//! slowdown distribution stays inside an SLO. One [`LoadShape`] — ramp
//! in, burst, steady soak, optionally heavy-tailed sizes and diurnal
//! modulation — is generated once per sweep, so BASS/BAR/HDS face the
//! identical arrival trace and all deltas are scheduling policy. Each
//! point runs through [`run_soak`], the bounded-memory driver: per-job
//! state is finalized into streaming sketches at completion, so the
//! sweep scales to arbitrarily long traces without the per-job outcome
//! list the classic stream keeps. The figure of merit is
//! **sustained jobs/hour**: the completion rate while the p95 slowdown
//! meets the target, zero once the tail blows through it. See
//! EXPERIMENTS.md.

use crate::runtime::CostModel;
use crate::scenario::{
    parallel_map, run_soak, AdmissionPolicy, SimSession, SoakConfig, Submission,
};
use crate::util::XorShift;
use crate::workload::LoadShape;

use super::fixtures::SchedulerKind;
use super::stream::stream_cluster;

/// One executed (scheduler) soak point. Distribution figures come off
/// the streaming sketches — exact up to the sketch cap, rank-bounded
/// beyond it — and the compaction counters double as the bounded-memory
/// evidence the acceptance checks assert on.
#[derive(Debug, Clone)]
pub struct SoakPoint {
    pub scheduler: &'static str,
    /// Jobs that ran to completion (excludes rejections).
    pub jobs: usize,
    pub queued: usize,
    pub mean_jt: f64,
    pub p95_jt: f64,
    pub mean_slowdown: f64,
    pub p95_slowdown: f64,
    /// Raw completion rate over the makespan.
    pub jobs_per_hour: f64,
    /// Jobs/hour while the p95 slowdown meets the target, else 0.
    pub sustained_jobs_per_hour: f64,
    pub makespan: f64,
    /// Periodic calendar compactions that actually ran.
    pub compactions: usize,
    /// High-water mark of live (undrained) engine records.
    pub peak_live_records: usize,
    /// Samples held by the quantile sketches at the end.
    pub retained_samples: usize,
}

/// Run one shaped trace through BASS/BAR/HDS soak drivers on up to
/// `threads` workers (each point is a hermetic session; results are
/// bitwise-identical to a serial run). The trace is generated once from
/// `seed`, so every scheduler faces the identical arrival sequence.
pub fn run_soak_sweep_with(
    shape: &LoadShape,
    seed: u64,
    policy: AdmissionPolicy,
    cfg: SoakConfig,
    cost: &CostModel,
    threads: usize,
) -> Vec<SoakPoint> {
    let mut rng = XorShift::new(seed);
    let subs: Vec<Submission> =
        shape.generate(&mut rng).into_iter().map(Submission::from).collect();
    let kinds = vec![SchedulerKind::Bass, SchedulerKind::Bar, SchedulerKind::Hds];
    parallel_map(kinds, threads, |kind| {
        let mut sess = SimSession::new(&stream_cluster(kind));
        let out = run_soak(&mut sess, subs.clone(), policy, cost, cfg);
        SoakPoint {
            scheduler: kind.label(),
            jobs: out.jobs,
            queued: out.queued_jobs,
            mean_jt: out.stats.mean_jt,
            p95_jt: out.stats.p95_jt,
            mean_slowdown: out.stats.mean_slowdown,
            p95_slowdown: out.p95_slowdown,
            jobs_per_hour: out.jobs_per_hour,
            sustained_jobs_per_hour: out.sustained_jobs_per_hour,
            makespan: out.makespan,
            compactions: out.compactions,
            peak_live_records: out.peak_live_records,
            retained_samples: out.retained_samples,
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{LoadStage, SizeDist};

    fn quick_jobs() -> usize {
        match std::env::var("BASS_BENCH_QUICK") {
            Ok(_) => 8,
            Err(_) => 18,
        }
    }

    fn shaped(jobs: usize) -> LoadShape {
        let ramp = jobs / 3;
        let spike = jobs / 6;
        LoadShape::new(
            vec![
                LoadStage::ramp(ramp, 60.0, 25.0),
                LoadStage::spike(spike, 25.0, 3.0),
                LoadStage::soak(jobs - ramp - spike, 30.0),
            ],
            SizeDist::Menu(vec![150.0, 300.0]),
            None,
        )
        .unwrap()
    }

    #[test]
    fn soak_sweep_reports_throughput_and_stays_compacted() {
        let cost = CostModel::rust_only();
        let jobs = quick_jobs();
        let pts = run_soak_sweep_with(
            &shaped(jobs),
            4242,
            AdmissionPolicy::default(),
            SoakConfig { gc_period_secs: 120.0, ..SoakConfig::defaults() },
            &cost,
            2,
        );
        assert_eq!(pts.len(), 3);
        for p in &pts {
            assert_eq!(p.jobs, jobs, "{}", p.scheduler);
            assert!(p.mean_jt > 0.0);
            assert!(p.p95_jt >= p.mean_jt * 0.5);
            assert!(p.p95_slowdown >= 1.0, "{}", p.scheduler);
            assert!(p.jobs_per_hour > 0.0);
            assert!(p.sustained_jobs_per_hour <= p.jobs_per_hour);
            assert!(p.makespan > 0.0);
            // bounded memory: periodic compaction ran and live records
            // never approached one-slot-per-task of the whole trace
            assert!(p.compactions >= 1, "{}", p.scheduler);
            assert!(
                p.peak_live_records < jobs * 8,
                "{}: peak {} live records",
                p.scheduler,
                p.peak_live_records
            );
        }
    }

    #[test]
    fn soak_sweep_is_deterministic_and_thread_invariant() {
        let cost = CostModel::rust_only();
        let shape = LoadShape::poisson(6, 40.0, vec![150.0, 300.0]).unwrap();
        let serial = run_soak_sweep_with(
            &shape,
            7,
            AdmissionPolicy::default(),
            SoakConfig::defaults(),
            &cost,
            1,
        );
        let fanned = run_soak_sweep_with(
            &shape,
            7,
            AdmissionPolicy::default(),
            SoakConfig::defaults(),
            &cost,
            3,
        );
        assert_eq!(serial.len(), fanned.len());
        for (a, b) in serial.iter().zip(&fanned) {
            assert_eq!(a.scheduler, b.scheduler);
            assert_eq!(a.mean_jt.to_bits(), b.mean_jt.to_bits());
            assert_eq!(a.p95_slowdown.to_bits(), b.p95_slowdown.to_bits());
            assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
            assert_eq!(a.compactions, b.compactions);
        }
    }

    #[test]
    fn schedulers_face_the_identical_shaped_trace() {
        // the sweep generates the trace once per seed; regenerating from
        // the same seed reproduces it arrival for arrival
        let shape = shaped(12);
        let mut r1 = XorShift::new(99);
        let mut r2 = XorShift::new(99);
        let a = shape.generate(&mut r1);
        let b = shape.generate(&mut r2);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at_secs.to_bits(), y.at_secs.to_bits());
            assert_eq!(x.data_mb.to_bits(), y.data_mb.to_bits());
        }
    }
}
