//! Reproduces the paper's Example 1 & 2 (Fig. 3 timelines, Fig. 4 bars):
//! HDS 39s, BAR 38s, BASS 35s, Pre-BASS 34s on the Fig. 2 testbed.
//!
//! Run: `cargo run --release --example paper_example1`

use bass::experiments::run_example1;
use bass::metrics::NodeTimeline;
use bass::runtime::CostModel;

fn main() {
    let cost = CostModel::auto();
    let outcomes = run_example1(&cost);
    println!("Fig. 4 — job completion time (paper vs reproduced)");
    println!("{:<10} {:>8} {:>10}", "scheduler", "paper", "reproduced");
    let paper = [("HDS", 39.0), ("BAR", 38.0), ("BASS", 35.0), ("Pre-BASS", 34.0)];
    for (o, (pname, pjt)) in outcomes.iter().zip(paper) {
        assert_eq!(o.scheduler, pname);
        println!("{:<10} {:>7.0}s {:>9.0}s", o.scheduler, pjt, o.executed_jt);
    }
    for o in &outcomes {
        println!("\nFig. 3 timeline — {} (executed JT {:.0}s)", o.scheduler, o.executed_jt);
        print!("{}", NodeTimeline::render(&o.timelines, 1.0));
    }
}
