//! Quickstart: build a small SDN-controlled cluster, submit one job
//! through BASS, and print the resulting schedule + metrics.
//!
//! Run: `cargo run --release --example quickstart`

use bass::cluster::Ledger;
use bass::hdfs::Namenode;
use bass::mapreduce::TaskSpec;
use bass::metrics::{JobMetrics, NodeTimeline};
use bass::runtime::CostModel;
use bass::sched::{Bass, SchedCtx, Scheduler};
use bass::sdn::Controller;
use bass::sim::{Engine, FlowNet};
use bass::topology::builders::tree_cluster;
use bass::util::{Secs, XorShift, BLOCK_MB};
use bass::workload::{JobKind, WorkloadBuilder};

fn main() -> anyhow::Result<()> {
    // 1. a 6-node cluster behind 2 OpenFlow switches, 100 Mbps links
    let (topo, nodes) = tree_cluster(2, 3, 100.0, 100.0);
    let caps: Vec<f64> = topo.links.iter().map(|l| l.capacity_mbps).collect();
    let mut ctrl = Controller::new(topo, 1.0); // 1s time slots
    let net = FlowNet::new(&caps);

    // 2. a 600MB wordcount job, blocks placed with 3 replicas
    let mut nn = Namenode::new();
    let mut rng = XorShift::new(42);
    let job = WorkloadBuilder::new(JobKind::Wordcount).build(0, 600.0, &nodes, &mut nn, &mut rng);
    let maps: Vec<TaskSpec> = job.maps().cloned().collect();
    println!("job {:?}: {} maps x {}MB, {} reduces", job.name, job.n_maps(), BLOCK_MB, job.n_reduces());

    // 3. schedule the map wave with BASS (XLA cost model if artifacts exist)
    let cost = CostModel::auto();
    let mut ledger = Ledger::new(nodes.len());
    let mut bass = Bass::new();
    let assignment = {
        let mut ctx = SchedCtx {
            controller: &mut ctrl,
            namenode: &nn,
            ledger: &mut ledger,
            authorized: nodes.clone(),
            now: Secs::ZERO,
            cost: &cost,
            node_speed: Vec::new(),
            down: Vec::new(),
            bw_aware_sources: true,
        };
        bass.schedule(&maps, None, &mut ctx)
    };
    println!(
        "scheduled: {} placements, locality {:.0}%, {} reserved remote transfers",
        assignment.placements.len(),
        assignment.locality_ratio() * 100.0,
        bass.remote_assignments
    );

    // 4. execute on the discrete-event engine and report
    let mut engine = Engine::new(net, vec![Secs::ZERO; nodes.len()]);
    engine.load(&assignment);
    let records = engine.run();
    let metrics = JobMetrics::from_records(&records, Secs::ZERO, None);
    println!("executed: {metrics}");
    println!("\nper-node timeline ('~' transfer, '=' compute, '*' remote):");
    print!("{}", NodeTimeline::render(&NodeTimeline::build(&records, nodes.len()), 2.0));
    Ok(())
}
