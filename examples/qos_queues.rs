//! Example 3: OpenFlow QoS queues (Q1/Q2/Q3) vs one shared queue for
//! shuffle traffic under varying background load.
//!
//! Run: `cargo run --release --example qos_queues`

use bass::experiments::run_example3;

fn main() {
    println!("Example 3 — shuffle completion, shared vs Q1/Q2/Q3 queues");
    println!("{:>10} {:>12} {:>12} {:>9}", "bg flows", "shared (s)", "queued (s)", "speedup");
    for bg in [0usize, 2, 5, 10] {
        let o = run_example3(bg);
        println!(
            "{:>10} {:>12.1} {:>12.1} {:>8.2}x",
            bg, o.shared_secs, o.queued_secs, o.speedup
        );
    }
}
