//! Heterogeneous-cluster study (extension; Guo & Fox [14] direction the
//! paper cites): half the nodes are K× slower. BASS's Eq. 4 argmin sees
//! per-node TP; HDS's locality-first greedy does not — but node-driven
//! pull scheduling self-balances, so the winner flips with K (results
//! are mixed; see EXPERIMENTS.md §Extensions for the honest numbers).
//!
//! Run: `cargo run --release --example hetero_cluster`

use bass::experiments::ablate_heterogeneity;
use bass::runtime::CostModel;

fn main() {
    let cost = CostModel::auto();
    println!("heterogeneous cluster: 3 fast + 3 (Kx slower) nodes, 16-map wave");
    println!("{:>6} {:>10} {:>10} {:>8}", "K", "BASS JT", "HDS JT", "gain");
    for k in [1.0, 1.5, 2.0, 3.0, 5.0] {
        let out = ablate_heterogeneity(k, &cost);
        let jt = |n: &str| out.iter().find(|(s, _)| *s == n).unwrap().1;
        println!(
            "{:>6.1} {:>9.1}s {:>9.1}s {:>7.1}%",
            k,
            jt("BASS"),
            jt("HDS"),
            (1.0 - jt("BASS") / jt("HDS")) * 100.0
        );
    }
}
