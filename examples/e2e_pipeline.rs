//! END-TO-END DRIVER: the full system on a realistic online workload.
//!
//! A submitter thread streams a 20-job synthetic trace (mixed
//! Wordcount/Sort, 150M-600M) into the coordinator leader over mpsc
//! channels; the leader schedules each arrival against live cluster
//! state (SDN bandwidth snapshot -> AOT XLA cost model -> slot
//! reservations) and executes it on the discrete-event cluster. Run for
//! all four schedulers; reports the paper's headline metric (mean/total
//! job completion time) and the BASS speedup. Results are recorded in
//! EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example e2e_pipeline`

use bass::coordinator::{ClusterSetup, Coordinator};
use bass::experiments::SchedulerKind;
use bass::runtime::CostModel;
use bass::util::XorShift;
use bass::workload::TraceGen;

fn main() {
    let n_jobs = 20;
    let gen = TraceGen { mean_interarrival_secs: 90.0, sizes_mb: vec![150.0, 300.0, 600.0] };
    println!("E2E: {n_jobs}-job online trace, 6-node cluster, background load\n");
    let mut summary = Vec::new();
    for kind in SchedulerKind::ALL {
        let mut rng = XorShift::new(2014); // identical trace for all schedulers
        let arrivals = gen.generate(n_jobs, &mut rng);
        let coord = Coordinator::new(ClusterSetup::default(), kind, CostModel::auto());
        let results = coord.run_trace(arrivals).expect("no submissions lost");
        let total: f64 = results.iter().map(|r| r.metrics.jt).sum();
        let mean = total / results.len() as f64;
        let mean_lr: f64 =
            results.iter().map(|r| r.metrics.lr).sum::<f64>() / results.len() as f64;
        println!("[{:<8}] mean JT {:>7.1}s   total {:>8.1}s   mean LR {:>5.1}%",
            kind.label(), mean, total, mean_lr * 100.0);
        summary.push((kind.label(), mean));
    }
    let hds = summary.iter().find(|(n, _)| *n == "HDS").unwrap().1;
    let bass = summary.iter().find(|(n, _)| *n == "BASS").unwrap().1;
    println!("\nheadline: BASS mean JT is {:.1}% lower than HDS ({:.1}s vs {:.1}s)",
        (1.0 - bass / hds) * 100.0, bass, hds);
}
