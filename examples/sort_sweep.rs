//! Table I(b) + Fig. 5 (Sort panel): the IO-bound sweep.
//!
//! Run: `cargo run --release --example sort_sweep [--full]`

use bass::experiments::{run_table1, Table1Config};
use bass::runtime::CostModel;
use bass::trace;
use bass::workload::JobKind;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let mut cfg = Table1Config::paper(JobKind::Sort);
    cfg.threads = 4; // hermetic cells: identical rows, less wall-clock
    if !full {
        cfg.sizes_mb = vec![150.0, 300.0, 600.0];
    }
    let rows = run_table1(&cfg, &CostModel::auto());
    println!("Table I(b) — Sort (reproduced)");
    print!("{}", trace::table1_csv(&rows));
}
