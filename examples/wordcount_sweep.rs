//! Table I(a) + Fig. 5 (Wordcount panel): the data-size sweep over
//! BASS / BAR / HDS with seeded background load.
//!
//! Run: `cargo run --release --example wordcount_sweep [--full]`
//! (`--full` includes the 1G and 5G points; default stops at 600M.)

use bass::experiments::{run_table1, Table1Config};
use bass::runtime::CostModel;
use bass::trace;
use bass::workload::JobKind;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let mut cfg = Table1Config::paper(JobKind::Wordcount);
    cfg.threads = 4; // hermetic cells: identical rows, less wall-clock
    if !full {
        cfg.sizes_mb = vec![150.0, 300.0, 600.0];
    }
    let rows = run_table1(&cfg, &CostModel::auto());
    println!("Table I(a) — Wordcount (reproduced)");
    print!("{}", trace::table1_markdown(&rows));
    println!("\nFig. 5 series (JT seconds):");
    for k in &cfg.schedulers {
        let series: Vec<String> = cfg
            .sizes_mb
            .iter()
            .map(|&s| {
                format!(
                    "{:.0}",
                    rows.iter()
                        .find(|r| r.scheduler == k.label() && r.data_mb == s)
                        .unwrap()
                        .metrics
                        .jt
                )
            })
            .collect();
        println!("  {:<8} {}", k.label(), series.join("\t"));
    }
}
