#!/usr/bin/env python3
"""Bench regression gate for the CI bench-smoke job.

Compares freshly measured BENCH_*.json files (written by
`cargo bench --bench scheduler_micro`) against the committed baselines
(copied aside before the bench overwrote them). Fails when any case's
mean regresses by more than --factor (default 2x).

Baselines with `"measured": false` or null means (committed from a
machine without the Rust toolchain) are skipped: the gate arms itself
automatically once real numbers are committed.

Usage:
  python3 tools/check_bench_regression.py --baseline-dir /tmp/baseline \
      BENCH_calendar.json BENCH_flownet.json BENCH_sched.json \
      BENCH_scale.json BENCH_stream.json

BENCH_stream.json covers the soak tier: the bounded-memory soak drain
over a shaped trace and the mid-trace checkpoint/resume round trip.
"""

import argparse
import json
import os
import sys


def load_cases(path):
    with open(path) as f:
        doc = json.load(f)
    return doc, {c["case"]: c for c in doc.get("cases", [])}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="+", help="regenerated BENCH_*.json files")
    ap.add_argument("--baseline-dir", required=True, help="directory holding the committed copies")
    ap.add_argument("--factor", type=float, default=2.0, help="max allowed mean slowdown")
    args = ap.parse_args()

    failures = []
    checked = 0
    for path in args.files:
        base_path = os.path.join(args.baseline_dir, os.path.basename(path))
        if not os.path.exists(base_path):
            print(f"[skip] {path}: no committed baseline")
            continue
        base_doc, base_cases = load_cases(base_path)
        if not base_doc.get("measured", False):
            print(f"[skip] {path}: baseline is an unmeasured placeholder")
            continue
        _, new_cases = load_cases(path)
        for name, base in base_cases.items():
            base_mean = base.get("mean_s")
            if base_mean is None or base_mean <= 0:
                print(f"[skip] {path}:{name}: baseline mean is null")
                continue
            new = new_cases.get(name)
            if new is None or new.get("mean_s") is None:
                failures.append(f"{path}:{name}: case missing from regenerated results")
                continue
            ratio = new["mean_s"] / base_mean
            checked += 1
            status = "FAIL" if ratio > args.factor else "ok"
            print(f"[{status}] {path}:{name}: {base_mean:.3e}s -> {new['mean_s']:.3e}s ({ratio:.2f}x)")
            if ratio > args.factor:
                failures.append(
                    f"{path}:{name}: mean regressed {ratio:.2f}x (> {args.factor:.1f}x allowed)"
                )

    if failures:
        print("\nbench regression gate FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    if checked == 0:
        print(
            "\nWARNING: bench regression gate is VACUOUS — 0 cases checked because every "
            "committed baseline is an unmeasured placeholder. Run `cargo bench --bench "
            "scheduler_micro` on a machine with a toolchain and commit the BENCH_*.json "
            "files to arm the gate."
        )
        return 0
    print(f"\nbench regression gate passed ({checked} cases checked)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
