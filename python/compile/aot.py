"""AOT pipeline: lower the L2 model to HLO *text* artifacts for Rust.

HLO text (NOT HloModuleProto.serialize()) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
bundled xla_extension 0.5.1 rejects (proto.id() <= INT_MAX); the text
parser reassigns ids and round-trips cleanly. See /opt/xla-example.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Emits:
  artifacts/cost_{M}x{N}.hlo.txt   one per VARIANTS entry in model.py
  artifacts/idle_{N}.hlo.txt       ProgressRate estimator variants
  artifacts/manifest.txt           "name m n path" rows for the Rust loader
"""

import argparse
import os

from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True).

    return_tuple=True means the Rust side unwraps with to_tuple() /
    to_tuple1() -- see runtime/exec.rs.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(out_dir: str) -> list[str]:
    os.makedirs(out_dir, exist_ok=True)
    manifest = []

    for (m, n) in model.VARIANTS:
        path = os.path.join(out_dir, f"cost_{m}x{n}.hlo.txt")
        text = to_hlo_text(model.lower_schedule_eval(m, n))
        with open(path, "w") as f:
            f.write(text)
        manifest.append(f"cost {m} {n} {os.path.basename(path)}")
        print(f"wrote {path} ({len(text)} chars)")

    for n in sorted({n for (_, n) in model.VARIANTS} | {256}):
        path = os.path.join(out_dir, f"idle_{n}.hlo.txt")
        text = to_hlo_text(model.lower_idle_estimate(n))
        with open(path, "w") as f:
            f.write(text)
        manifest.append(f"idle 0 {n} {os.path.basename(path)}")
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(out_dir, "manifest.txt")
    with open(mpath, "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {mpath} ({len(manifest)} artifacts)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    build(args.out_dir)


if __name__ == "__main__":
    main()
