"""Pure-jnp oracle for the BASS scheduling cost model (Eq. 1-3 of the paper).

This is the CORE correctness signal: the Pallas kernel in cost_matrix.py and
the Rust fallback evaluator (rust/src/sched/cost.rs) must both agree with
this reference bit-for-bit on the same f32 inputs.

Semantics
---------
Given m pending tasks and n candidate nodes:

  sz    f32[m]    input split size per task (MB)
  bw    f32[m,n]  effective available bandwidth from TK_i's data source
                  to ND_j (MB/s); <= 0 means "no path"
  tp    f32[m,n]  computation time TP_{i,j} (s)
  local f32[m,n]  1.0 where ND_j already stores a replica of TK_i's split
  idle  f32[n]    node available idle time YI_j (s)
  ts    f32[1]    time-slot duration (s), for the slot-demand output

  TM_{i,j} = 0                      if local
           = sz_i / bw_{i,j}        if bw > 0          (Eq. 1)
           = +INF                   otherwise (unreachable)
  TE_{i,j} = TM_{i,j} + TP_{i,j}                       (Eq. 2)
  YC_{i,j} = TE_{i,j} + YI_j                           (Eq. 3)
  slots    = ceil(TM / ts)          (0 where local)
"""

import jax.numpy as jnp

INF = jnp.float32(3.0e38)
EPS = jnp.float32(1e-9)


def transfer_time_ref(sz, bw, local):
    """TM matrix (Eq. 1) with locality masking and unreachability."""
    sz = sz.astype(jnp.float32)
    bw = bw.astype(jnp.float32)
    tm = sz[:, None] / jnp.maximum(bw, EPS)
    tm = jnp.where(bw <= 0.0, INF, tm)
    return jnp.where(local > 0.0, jnp.float32(0.0), tm)


def cost_matrix_ref(sz, bw, tp, local, idle, ts):
    """Full Eq. 1-3 evaluation.

    Returns (yc, tm, slots, best_idx, best_cost):
      yc        f32[m,n]  completion-time matrix YC
      tm        f32[m,n]  transfer-time matrix TM
      slots     f32[m,n]  time-slot demand ceil(TM/ts)
      best_idx  i32[m]    argmin_j YC  (Objective Function, Eq. 4)
      best_cost f32[m]    min_j YC
    """
    tm = transfer_time_ref(sz, bw, local)
    te = tm + tp.astype(jnp.float32)
    yc = te + idle.astype(jnp.float32)[None, :]
    slots = jnp.ceil(tm / jnp.maximum(ts.astype(jnp.float32)[0], EPS))
    slots = jnp.where(tm >= INF, INF, slots)
    best_idx = jnp.argmin(yc, axis=1).astype(jnp.int32)
    best_cost = jnp.min(yc, axis=1)
    return yc, tm, slots, best_idx, best_cost


def idle_estimate_ref(progress_score, progress_rate):
    """ProgressRate idle-time estimator (Section V-A of the paper).

    YI = (1 - ProgressScore) / ProgressRate, with rate <= 0 (task not
    started / no signal) mapping to INF.
    """
    ps = jnp.clip(progress_score.astype(jnp.float32), 0.0, 1.0)
    pr = progress_rate.astype(jnp.float32)
    est = (jnp.float32(1.0) - ps) / jnp.maximum(pr, EPS)
    return jnp.where(pr <= 0.0, INF, est)
