"""L1 Pallas kernel: tiled BASS cost-matrix evaluation (Eq. 1-3).

The (m x n) task-by-node matrix is tiled into (BM x BN) VMEM blocks via
BlockSpec. Each grid step streams one block of bw/tp/local plus the matching
sz row-slice and idle column-slice, and emits the YC and TM blocks in a
single fused pass (no intermediate materialization in HBM).

TPU mapping (see DESIGN.md #hardware-adaptation): this op is elementwise +
broadcast, i.e. VPU-bound, so the tiling goal is VMEM residency and single
HBM pass, not MXU utilization. Default blocks of 128x128 f32 are 64 KiB per
matrix operand - four operands plus two outputs fit in ~384 KiB of VMEM,
far under the ~16 MiB budget, leaving room for double-buffering by the
Mosaic pipeliner.

interpret=True ALWAYS: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret-mode lowers the kernel to plain HLO so the same
artifact runs under the Rust runtime (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Plain Python floats: pallas kernels cannot capture traced jnp constants.
INF = 3.0e38
EPS = 1e-9

# Default VMEM tile. Both must divide the (padded) problem shape; callers pad
# to the artifact shape grid (see aot.py / model.py).
DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_N = 128


def _cost_kernel(sz_ref, bw_ref, tp_ref, local_ref, idle_ref, yc_ref, tm_ref):
    """One (BM, BN) block: fused Eq. 1-3.

    sz_ref    f32[BM, 1]  split sizes for this task-tile
    bw_ref    f32[BM, BN] effective bandwidth block
    tp_ref    f32[BM, BN] compute-time block
    local_ref f32[BM, BN] replica-locality mask block
    idle_ref  f32[1, BN]  node idle times for this node-tile
    yc_ref    f32[BM, BN] out: completion-time block
    tm_ref    f32[BM, BN] out: transfer-time block
    """
    sz = sz_ref[...]          # (BM, 1), broadcasts over columns
    bw = bw_ref[...]
    tp = tp_ref[...]
    local = local_ref[...]
    idle = idle_ref[...]      # (1, BN), broadcasts over rows

    tm = sz / jnp.maximum(bw, jnp.float32(EPS))
    tm = jnp.where(bw <= 0.0, jnp.float32(INF), tm)
    tm = jnp.where(local > 0.0, jnp.float32(0.0), tm)
    tm_ref[...] = tm
    yc_ref[...] = tm + tp + idle


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def cost_matrix_pallas(sz, bw, tp, local, idle,
                       block_m=DEFAULT_BLOCK_M, block_n=DEFAULT_BLOCK_N):
    """Tiled Pallas evaluation of (YC, TM) over an (m, n) problem.

    Shapes must be multiples of the block shape; model.schedule_eval pads.
    Returns (yc, tm), each f32[m, n].
    """
    m, n = bw.shape
    bm = min(block_m, m)
    bn = min(block_n, n)
    if m % bm or n % bn:
        raise ValueError(f"problem {m}x{n} not divisible by block {bm}x{bn}")
    grid = (m // bm, n // bn)

    # sz enters as a column (m,1), idle as a row (1,n): keeps every ref 2-D,
    # which is both the TPU-friendly layout and what interpret mode expects.
    sz2 = sz.reshape(m, 1).astype(jnp.float32)
    idle2 = idle.reshape(1, n).astype(jnp.float32)

    yc, tm = pl.pallas_call(
        _cost_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((m, n), jnp.float32),
        ],
        interpret=True,  # CPU-PJRT execution path; see module docstring
    )(sz2, bw.astype(jnp.float32), tp.astype(jnp.float32),
      local.astype(jnp.float32), idle2)
    return yc, tm


def vmem_bytes(block_m, block_n):
    """Static VMEM footprint estimate for one grid step (f32 operands).

    5 block inputs (sz column, bw, tp, local, idle row) + 2 block outputs.
    Used by the structural perf report in EXPERIMENTS.md #perf.
    """
    mat = block_m * block_n * 4
    return 4 * 0 + 3 * mat + block_m * 4 + block_n * 4 + 2 * mat
