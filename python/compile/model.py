"""L2 JAX model: the BASS batched scheduling cost model.

This is the computation the Rust coordinator executes on its hot path (via
the AOT artifact, never via Python): given the SDN controller's bandwidth
snapshot and the cluster's idle-time ledger, evaluate Eq. 1-3 for every
pending task x candidate node, and reduce to the per-task optimum
(Objective Function, Eq. 4) plus the time-slot demand of each placement.

The elementwise core (YC / TM blocks) runs in the L1 Pallas kernel; the
row-reductions (argmin / min) and slot quantization stay in jnp so XLA fuses
them with the kernel output in one HLO module.
"""

import jax
import jax.numpy as jnp

from .kernels import cost_matrix as cm
from .kernels.ref import EPS, INF

# Artifact variants built by aot.py. Rust picks the smallest variant that
# fits the live (m, n) and pads; names must match runtime/artifacts.rs.
VARIANTS = ((16, 8), (64, 16), (256, 64))


def schedule_eval(sz, bw, tp, local, idle, ts):
    """Full scheduling evaluation; the single exported computation.

    Inputs (see kernels/ref.py for semantics):
      sz f32[m], bw f32[m,n], tp f32[m,n], local f32[m,n],
      idle f32[n], ts f32[1]

    Returns (yc, tm, slots, best_idx, best_cost).
    """
    m, n = bw.shape
    # Block shape: full problem if it fits one tile, else the default grid.
    bm = m if m <= cm.DEFAULT_BLOCK_M else cm.DEFAULT_BLOCK_M
    bn = n if n <= cm.DEFAULT_BLOCK_N else cm.DEFAULT_BLOCK_N
    yc, tm = cm.cost_matrix_pallas(sz, bw, tp, local, idle,
                                   block_m=bm, block_n=bn)
    slots = jnp.ceil(tm / jnp.maximum(ts.astype(jnp.float32)[0], EPS))
    slots = jnp.where(tm >= INF, INF, slots)
    best_idx = jnp.argmin(yc, axis=1).astype(jnp.int32)
    best_cost = jnp.min(yc, axis=1)
    return yc, tm, slots, best_idx, best_cost


def idle_estimate(progress_score, progress_rate):
    """ProgressRate estimator (Section V-A), exported as its own artifact."""
    ps = jnp.clip(progress_score.astype(jnp.float32), 0.0, 1.0)
    pr = progress_rate.astype(jnp.float32)
    est = (jnp.float32(1.0) - ps) / jnp.maximum(pr, EPS)
    return (jnp.where(pr <= 0.0, INF, est),)


def lower_schedule_eval(m, n):
    """jax.jit(...).lower for a fixed (m, n) artifact variant."""
    f32 = jnp.float32
    specs = (
        jax.ShapeDtypeStruct((m,), f32),      # sz
        jax.ShapeDtypeStruct((m, n), f32),    # bw
        jax.ShapeDtypeStruct((m, n), f32),    # tp
        jax.ShapeDtypeStruct((m, n), f32),    # local
        jax.ShapeDtypeStruct((n,), f32),      # idle
        jax.ShapeDtypeStruct((1,), f32),      # ts
    )
    return jax.jit(schedule_eval).lower(*specs)


def lower_idle_estimate(n):
    f32 = jnp.float32
    spec = jax.ShapeDtypeStruct((n,), f32)
    return jax.jit(idle_estimate).lower(spec, spec)
