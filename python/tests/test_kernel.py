"""Pallas kernel vs pure-jnp oracle: the core L1 correctness signal.

hypothesis sweeps shapes, block shapes and input regimes; every case must
match kernels/ref.py exactly (atol=0) because both paths compute the same
f32 expression tree.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import cost_matrix as cm
from compile.kernels import ref


def _mk_inputs(rng, m, n, *, neg_bw=True, locality=0.3):
    sz = rng.uniform(0.0, 5000.0, m).astype(np.float32)
    lo = -5.0 if neg_bw else 1e-3
    bw = rng.uniform(lo, 120.0, (m, n)).astype(np.float32)
    tp = rng.uniform(0.0, 900.0, (m, n)).astype(np.float32)
    local = (rng.random((m, n)) < locality).astype(np.float32)
    idle = rng.uniform(0.0, 200.0, n).astype(np.float32)
    ts = np.array([1.0], np.float32)
    return sz, bw, tp, local, idle, ts


def _run_both(sz, bw, tp, local, idle, ts, bm, bn):
    got = cm.cost_matrix_pallas(
        jnp.array(sz), jnp.array(bw), jnp.array(tp), jnp.array(local),
        jnp.array(idle), block_m=bm, block_n=bn)
    want_yc, want_tm, *_ = ref.cost_matrix_ref(
        jnp.array(sz), jnp.array(bw), jnp.array(tp), jnp.array(local),
        jnp.array(idle), jnp.array(ts))
    return got, (want_yc, want_tm)


@settings(max_examples=40, deadline=None)
@given(
    mb=st.integers(1, 6), nb=st.integers(1, 6),
    bm=st.sampled_from([4, 8, 16]), bn=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_shapes(mb, nb, bm, bn, seed):
    """Grid sweep: any multiple of any block shape matches the oracle."""
    m, n = mb * bm, nb * bn
    rng = np.random.default_rng(seed)
    args = _mk_inputs(rng, m, n)
    (yc, tm), (wyc, wtm) = _run_both(*args, bm, bn)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(wyc), atol=0)
    np.testing.assert_allclose(np.asarray(tm), np.asarray(wtm), atol=0)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       locality=st.floats(0.0, 1.0))
def test_kernel_locality_regimes(seed, locality):
    """From all-remote to all-local, TM respects the locality mask."""
    rng = np.random.default_rng(seed)
    sz, bw, tp, local, idle, ts = _mk_inputs(rng, 16, 8, locality=locality)
    (yc, tm), (wyc, wtm) = _run_both(sz, bw, tp, local, idle, ts, 16, 8)
    tm = np.asarray(tm)
    np.testing.assert_allclose(tm, np.asarray(wtm), atol=0)
    assert (tm[local > 0] == 0.0).all(), "local placements must have TM=0"


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_kernel_unreachable_is_inf(seed):
    """bw <= 0 and not local => YC >= INF (node never wins argmin)."""
    rng = np.random.default_rng(seed)
    sz, bw, tp, local, idle, ts = _mk_inputs(rng, 8, 8)
    bw[:, 0] = -1.0
    local[:, 0] = 0.0
    (yc, tm), _ = _run_both(sz, bw, tp, local, idle, ts, 8, 8)
    assert (np.asarray(tm)[:, 0] >= cm.INF).all()
    assert (np.asarray(yc)[:, 0] >= cm.INF).all()


def test_kernel_rejects_indivisible_grid():
    rng = np.random.default_rng(0)
    sz, bw, tp, local, idle, ts = _mk_inputs(rng, 10, 6)
    with pytest.raises(ValueError, match="not divisible"):
        cm.cost_matrix_pallas(jnp.array(sz), jnp.array(bw), jnp.array(tp),
                              jnp.array(local), jnp.array(idle),
                              block_m=4, block_n=4)


@pytest.mark.parametrize("bm,bn", [(4, 4), (8, 8), (16, 8), (128, 128)])
def test_vmem_budget(bm, bn):
    """Structural perf check: the block working set stays far under VMEM."""
    assert cm.vmem_bytes(bm, bn) < 16 * 1024 * 1024


def test_paper_example1_numbers():
    """TK_1 of Example 1: YC on ND_1 (remote, 5s move) = 17s beats the
    data-local ND_2 = 18s — the paper's canonical BASS decision."""
    # nodes: ND_1..ND_4, idle = 3, 9, 20, 7; block 64MB at 100Mbps ~= 5s
    # (the paper rounds 5.12s to 5s; we use bw = 12.8 MB/s so TM = 5.0s).
    sz = np.array([64.0], np.float32)                      # MB
    bw = np.array([[12.8, 12.8, 12.8, 12.8]], np.float32)  # 100Mbps
    tp = np.full((1, 4), 9.0, np.float32)
    local = np.array([[0.0, 1.0, 1.0, 0.0]], np.float32)   # replicas ND_2, ND_3
    idle = np.array([3.0, 9.0, 20.0, 7.0], np.float32)
    ts = np.array([1.0], np.float32)
    yc, tm, slots, idx, cost = ref.cost_matrix_ref(
        jnp.array(sz), jnp.array(bw), jnp.array(tp), jnp.array(local),
        jnp.array(idle), jnp.array(ts))
    yc = np.asarray(yc)[0]
    assert yc[1] == pytest.approx(18.0)          # local ND_2: 0+9+9
    assert yc[0] == pytest.approx(17.0)          # remote ND_1: 5+9+3
    assert int(idx[0]) == 0                      # BASS picks ND_1
    assert int(np.asarray(slots)[0, 0]) == 5     # 5 time slots reserved
