"""L2 model tests: schedule_eval shapes/semantics + idle estimator +
artifact lowering (HLO text emission) sanity."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model, aot
from compile.kernels import ref


def _inputs(m, n, seed=0):
    rng = np.random.default_rng(seed)
    return (
        jnp.array(rng.uniform(1, 2000, m).astype(np.float32)),
        jnp.array(rng.uniform(0.1, 100, (m, n)).astype(np.float32)),
        jnp.array(rng.uniform(1, 600, (m, n)).astype(np.float32)),
        jnp.array((rng.random((m, n)) < 0.4).astype(np.float32)),
        jnp.array(rng.uniform(0, 60, n).astype(np.float32)),
        jnp.array([1.0], np.float32),
    )


@pytest.mark.parametrize("m,n", list(model.VARIANTS))
def test_schedule_eval_variant_shapes(m, n):
    yc, tm, slots, idx, cost = model.schedule_eval(*_inputs(m, n))
    assert yc.shape == (m, n) and tm.shape == (m, n)
    assert slots.shape == (m, n)
    assert idx.shape == (m,) and idx.dtype == jnp.int32
    assert cost.shape == (m,)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_schedule_eval_matches_ref(seed):
    args = _inputs(16, 8, seed)
    got = model.schedule_eval(*args)
    want = ref.cost_matrix_ref(*args)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=0)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_argmin_is_objective_function(seed):
    """Eq. 4: the returned node minimizes YC for every task."""
    args = _inputs(16, 8, seed)
    yc, _, _, idx, cost = model.schedule_eval(*args)
    yc, idx, cost = map(np.asarray, (yc, idx, cost))
    for i in range(yc.shape[0]):
        assert yc[i, idx[i]] == cost[i] == yc[i].min()


def test_idle_estimate_formula():
    ps = jnp.array([0.0, 0.5, 1.0, 0.25], jnp.float32)
    pr = jnp.array([0.1, 0.5, 1.0, 0.0], jnp.float32)
    (est,) = model.idle_estimate(ps, pr)
    est = np.asarray(est)
    assert est[0] == pytest.approx(10.0)
    assert est[1] == pytest.approx(1.0)
    assert est[2] == pytest.approx(0.0)
    assert est[3] >= 3.0e38  # no progress signal -> unknown/INF


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_idle_estimate_monotone_in_progress(seed):
    """More progress at the same rate => no later idle time."""
    rng = np.random.default_rng(seed)
    pr = jnp.array(rng.uniform(0.01, 2.0, 8).astype(np.float32))
    ps_lo = jnp.array(rng.uniform(0.0, 0.5, 8).astype(np.float32))
    ps_hi = ps_lo + 0.3
    (lo,), (hi,) = model.idle_estimate(ps_lo, pr), model.idle_estimate(ps_hi, pr)
    assert (np.asarray(hi) <= np.asarray(lo)).all()


def test_lowering_emits_parsable_hlo(tmp_path):
    """HLO text must contain an ENTRY computation and a tuple root —
    the contract runtime/loader.rs depends on."""
    text = aot.to_hlo_text(model.lower_schedule_eval(16, 8))
    assert "ENTRY" in text
    assert "f32[16,8]" in text
    idle_text = aot.to_hlo_text(model.lower_idle_estimate(16))
    assert "ENTRY" in idle_text


def test_aot_build_manifest(tmp_path):
    manifest = aot.build(str(tmp_path))
    names = [row.split()[0] for row in manifest]
    assert names.count("cost") == len(model.VARIANTS)
    assert (tmp_path / "manifest.txt").exists()
    for (m, n) in model.VARIANTS:
        assert (tmp_path / f"cost_{m}x{n}.hlo.txt").stat().st_size > 0
